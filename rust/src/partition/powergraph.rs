//! PowerGraph-style edge-partition baselines (Gonzalez et al., OSDI'12),
//! as described in the paper §3.3: both stream over all edges once.
//!
//! * `random_partition` — assign each edge to a uniformly random block.
//! * `greedy_partition` — prefer blocks already holding an endpoint;
//!   among candidates, pick the least loaded; cap loads for balance.
//!
//! The paper shows both produce *worse* quality than even the default
//! schedule on GPU-style workloads — we must reproduce that result
//! (Fig 6 "Random quality" / "Greedy quality" columns).

use crate::graph::Graph;
use crate::util::rng::Pcg32;

use super::quality::EdgePartition;

/// Uniform random assignment.
pub fn random_partition(g: &Graph, k: usize, seed: u64) -> EdgePartition {
    let mut rng = Pcg32::new(seed);
    EdgePartition::new(k, (0..g.m()).map(|_| rng.gen_range(k) as u32).collect())
}

/// PowerGraph greedy heuristic.  For edge (u, v) with block sets
/// B(u), B(v) already holding the endpoints:
///   1. if B(u) ∩ B(v) ≠ ∅ → least-loaded block in the intersection;
///   2. else if B(u) ∪ B(v) ≠ ∅ → least-loaded block in the union;
///   3. else → least-loaded block overall.
/// A block at the hard cap (balance guarantee) is never chosen.
pub fn greedy_partition(g: &Graph, k: usize, seed: u64) -> EdgePartition {
    let mut rng = Pcg32::new(seed);
    let cap = (g.m().div_ceil(k) as f64 * 1.05).ceil() as usize + 1;
    let mut loads = vec![0usize; k];
    // block sets per vertex, kept as sorted small vecs (degrees are small
    // relative to k in GPU workloads; worst case this is Σ p_v memory).
    let mut vsets: Vec<Vec<u32>> = vec![Vec::new(); g.n];
    let mut assign = vec![0u32; g.m()];

    let pick_least = |cands: &mut dyn Iterator<Item = u32>,
                          loads: &[usize],
                          rng: &mut Pcg32|
     -> Option<u32> {
        let mut best: Option<(usize, u32)> = None;
        let mut ties = 0usize;
        for b in cands {
            let l = loads[b as usize];
            if l >= cap {
                continue;
            }
            match best {
                None => {
                    best = Some((l, b));
                    ties = 1;
                }
                Some((bl, _)) if l < bl => {
                    best = Some((l, b));
                    ties = 1;
                }
                Some((bl, _)) if l == bl => {
                    // reservoir tie-break for unbiased choice
                    ties += 1;
                    if rng.gen_range(ties) == 0 {
                        best = Some((l, b));
                    }
                }
                _ => {}
            }
        }
        best.map(|(_, b)| b)
    };

    for (e, &(u, v)) in g.edges.iter().enumerate() {
        let bu = &vsets[u as usize];
        let bv = &vsets[v as usize];
        let inter: Vec<u32> = bu.iter().filter(|b| bv.contains(b)).copied().collect();
        let chosen = if !inter.is_empty() {
            pick_least(&mut inter.iter().copied(), &loads, &mut rng)
        } else {
            None
        }
        .or_else(|| {
            let union: Vec<u32> = {
                let mut s = bu.clone();
                for &b in bv {
                    if !s.contains(&b) {
                        s.push(b);
                    }
                }
                s
            };
            if union.is_empty() {
                None
            } else {
                pick_least(&mut union.iter().copied(), &loads, &mut rng)
            }
        })
        .or_else(|| pick_least(&mut (0..k as u32), &loads, &mut rng))
        .unwrap_or_else(|| {
            // everything at cap (can't happen with cap > m/k, but stay safe)
            (0..k).min_by_key(|&b| loads[b]).unwrap() as u32
        });

        assign[e] = chosen;
        loads[chosen as usize] += 1;
        for w in [u, v] {
            let set = &mut vsets[w as usize];
            if !set.contains(&chosen) {
                set.push(chosen);
            }
        }
    }
    EdgePartition::new(k, assign)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::quality::{balance_factor, vertex_cut_cost};
    use crate::partition::default_sched::default_partition;

    #[test]
    fn random_is_valid_and_roughly_balanced() {
        let g = gen::cfd_mesh(20, 20, 1);
        let p = random_partition(&g, 8, 42);
        assert_eq!(p.assign.len(), g.m());
        assert!(balance_factor(&p) < 1.5);
    }

    #[test]
    fn greedy_respects_cap() {
        let g = gen::power_law(1000, 3, 2);
        let p = greedy_partition(&g, 16, 7);
        assert!(balance_factor(&p) < 1.12, "bf={}", balance_factor(&p));
    }

    #[test]
    fn greedy_beats_random() {
        let g = gen::cfd_mesh(30, 30, 3);
        let k = 16;
        let r = vertex_cut_cost(&g, &random_partition(&g, k, 1));
        let gr = vertex_cut_cost(&g, &greedy_partition(&g, k, 1));
        assert!(gr < r, "greedy {gr} !< random {r}");
    }

    #[test]
    fn random_is_worse_than_default_on_mesh() {
        // the paper's Fig 6 observation: random/greedy lose to default
        // contiguous scheduling on locality-rich inputs
        let g = gen::grid_mesh(40, 40);
        let k = 12;
        let d = vertex_cut_cost(&g, &default_partition(g.m(), k));
        let r = vertex_cut_cost(&g, &random_partition(&g, k, 3));
        assert!(r > d, "random {r} !> default {d}");
    }

    #[test]
    fn deterministic_given_seed() {
        let g = gen::power_law(500, 2, 9);
        let a = greedy_partition(&g, 8, 5).assign;
        let b = greedy_partition(&g, 8, 5).assign;
        assert_eq!(a, b);
    }
}
