//! The EP model: balanced edge partitioning via clone-and-connect
//! (paper §3.2–3.4, Definitions 3–4, Theorems 1–2).
//!
//! Transformation (Definition 3): every vertex v of degree d is replaced
//! by d *cloned vertices*, one per incident edge; each original edge
//! keeps its two clones as endpoints; each vertex's clones are chained
//! into a path by d−1 *auxiliary edges* (we connect in index order, the
//! paper's practical choice).  Original edges get a huge weight so a
//! balanced min-cut vertex partition only ever cuts auxiliary edges;
//! reconstruction (Definition 4) reads each original edge's block off
//! its (co-located) clone endpoints.
//!
//! Implementation note: heavy-edge matching contracts every original
//! edge in its first pass — each clone has exactly one heavy incident
//! edge, whose partner's unique heavy edge points straight back, so the
//! pair always matches (no conflicts are possible).  We perform that
//! first contraction *deterministically* during the transform, yielding
//! the "task graph": one vertex per original edge (weight = tasks = 1),
//! auxiliary edges between tasks that share a data object.  This is
//! exactly the clone-and-connect graph after one guaranteed coarsening
//! level, and makes "no original edge is cut" structural rather than
//! weight-enforced.  `clone_graph()` still materializes the explicit
//! transformed graph for the theory-facing tests (Theorem 1).

use crate::graph::Graph;
use crate::util::rng::Pcg32;

use super::quality::EdgePartition;
use super::vertex::{self, VpOpts, WGraph};

/// Weight assigned to original edges in the explicit clone graph.
pub const ORIG_EDGE_WEIGHT: i64 = 1 << 40;

/// Below this many tasks, recursive bisection is used even when
/// `fast_kway` is set (it is cheap there and noticeably better on small
/// meshes); above it, the single-coarsening k-way scheme — whose
/// uncoarsening now runs the gain-bucket k-way FM refinement
/// (`vertex::kway_refine_ws`, PERF.md §3) — wins on time.
pub const FAST_KWAY_MIN_TASKS: usize = 200_000;

/// How a vertex's clones are chained (ablation: the paper claims any
/// order is legal; `Index` is its practical choice).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChainOrder {
    Index,
    Random,
}

#[derive(Clone, Debug)]
pub struct EpOpts {
    pub vp: VpOpts,
    pub chain: ChainOrder,
    /// true → single-coarsening k-way scheme (3-4x faster, the paper's
    /// low-overhead requirement); false → recursive bisection with FM at
    /// every level (higher quality on thin/banded graphs).  See the
    /// `kway vs RB` ablation in EXPERIMENTS.md.
    pub fast_kway: bool,
}

impl Default for EpOpts {
    fn default() -> Self {
        EpOpts { vp: VpOpts::default(), chain: ChainOrder::Index, fast_kway: true }
    }
}

/// The contracted transform: task graph with one vertex per original
/// edge and auxiliary unit edges chaining each data object's incident
/// tasks.  Parallel aux edges (two tasks sharing both endpoints) are
/// merged by weight.
///
/// The index-order chain is the production path and is built directly
/// into CSR: the incidence lists of `Graph` are already in ascending
/// edge order, so chaining needs no sort, and a two-pass counting build
/// plus stamp dedup replaces the edge-tuple + sort-merge pipeline
/// (perf rewrite; see PERF.md).
pub fn task_graph(g: &Graph, chain: ChainOrder, seed: u64) -> WGraph {
    let m = g.m();
    match chain {
        ChainOrder::Index => {
            // pass 1: aux degree per task
            let mut deg = vec![0u32; m];
            for v in 0..g.n as u32 {
                for w in g.incident(v).windows(2) {
                    let (a, b) = (w[0].0, w[1].0);
                    if a != b {
                        deg[a as usize] += 1;
                        deg[b as usize] += 1;
                    }
                }
            }
            let mut xadj = vec![0u32; m + 1];
            for t in 0..m {
                xadj[t + 1] = xadj[t] + deg[t];
            }
            // pass 2: scatter (duplicates merged by from_csr_dedup)
            let mut cursor: Vec<u32> = xadj[..m].to_vec();
            let total = xadj[m] as usize;
            let mut adjncy = vec![0u32; total];
            let adjwgt = vec![1i64; total];
            for v in 0..g.n as u32 {
                for w in g.incident(v).windows(2) {
                    let (a, b) = (w[0].0, w[1].0);
                    if a != b {
                        adjncy[cursor[a as usize] as usize] = b;
                        cursor[a as usize] += 1;
                        adjncy[cursor[b as usize] as usize] = a;
                        cursor[b as usize] += 1;
                    }
                }
            }
            WGraph::from_csr_dedup(m, vec![1i64; m], xadj, adjncy, adjwgt)
        }
        ChainOrder::Random => {
            // ablation path: chain order is randomized per data object
            let mut rng = Pcg32::new(seed);
            let mut aux: Vec<(u32, u32, i64)> = Vec::with_capacity(2 * m);
            let mut scratch: Vec<u32> = Vec::new();
            for v in 0..g.n as u32 {
                let inc = g.incident(v);
                if inc.len() < 2 {
                    continue;
                }
                scratch.clear();
                scratch.extend(inc.iter().map(|&(e, _)| e));
                rng.shuffle(&mut scratch);
                for w in scratch.windows(2) {
                    if w[0] != w[1] {
                        aux.push((w[0], w[1], 1));
                    }
                }
            }
            WGraph::from_edges(m, vec![1i64; m], &aux)
        }
    }
}

/// The explicit clone-and-connect graph D' (Definition 3), for tests /
/// theory.  Returns (graph, clone_owner) where `clone_owner[c] =
/// (original vertex, original edge)` for each clone vertex c.
pub fn clone_graph(g: &Graph, chain: ChainOrder, seed: u64) -> (WGraph, Vec<(u32, u32)>) {
    let mut rng = Pcg32::new(seed);
    // clone ids: for edge e = (u, v), clone 2e belongs to u, 2e+1 to v.
    let m = g.m();
    let n_clones = 2 * m;
    let mut owner = vec![(0u32, 0u32); n_clones];
    let mut edges: Vec<(u32, u32, i64)> = Vec::with_capacity(3 * m);
    for (e, &(u, v)) in g.edges.iter().enumerate() {
        let e = e as u32;
        owner[2 * e as usize] = (u, e);
        owner[2 * e as usize + 1] = (v, e);
        edges.push((2 * e, 2 * e + 1, ORIG_EDGE_WEIGHT));
    }
    // chain each vertex's clones
    let mut scratch: Vec<u32> = Vec::new();
    for v in 0..g.n as u32 {
        scratch.clear();
        for &(e, _) in g.incident(v) {
            let (a, b) = g.edges[e as usize];
            // which side(s) of edge e are v's clones? (both for a loop)
            if a == v {
                scratch.push(2 * e);
            }
            if b == v {
                scratch.push(2 * e + 1);
            }
        }
        match chain {
            ChainOrder::Index => scratch.sort_unstable(),
            ChainOrder::Random => rng.shuffle(&mut scratch),
        }
        for w in scratch.windows(2) {
            edges.push((w[0], w[1], 1));
        }
    }
    (WGraph::from_edges(n_clones, vec![1i64; n_clones], &edges), owner)
}

/// The EP algorithm: transform → balanced vertex partition → reconstruct.
pub fn partition_edges(g: &Graph, k: usize, opts: &EpOpts) -> EdgePartition {
    if g.m() == 0 {
        return EdgePartition::new(k.max(1), vec![]);
    }
    let tg = task_graph(g, opts.chain, opts.vp.seed);
    // fast k-way only pays off on large graphs; below the threshold the
    // recursive-bisection path is both cheap and higher quality.
    // `Mode::Lp` always takes the single-chain path: its engines live
    // behind the Coarsener/Refiner seams of `partition_kway`, and a
    // mode request must exercise them at every size (CI smokes and
    // property tests run far below the fast-kway threshold).
    let single_chain = (opts.fast_kway && tg.n >= FAST_KWAY_MIN_TASKS)
        || opts.vp.mode == vertex::Mode::Lp;
    let part = if single_chain {
        vertex::partition_kway(&tg, k, &opts.vp)
    } else {
        vertex::partition_kway_rb(&tg, k, &opts.vp)
    };
    EdgePartition::new(k, part)
}

/// Enforce a hard per-block task cap (the thread-block size: a block of
/// `cap` threads can run at most `cap` tasks).  Greedily evicts the
/// cheapest task (by vertex-cut delta) from each overloaded block into
/// the least-loaded block.  Terminates: every move strictly reduces the
/// overload mass.
pub fn rebalance_to_cap(g: &Graph, p: &mut EdgePartition, cap: usize) {
    let k = p.k;
    let mut loads = vec![0usize; k];
    for &b in &p.assign {
        loads[b as usize] += 1;
    }
    if loads.iter().all(|&l| l <= cap) {
        return;
    }
    assert!(cap * k >= g.m(), "cap {cap} x k {k} cannot hold {} tasks", g.m());
    // per-vertex per-block incidence counts (sparse: vertices touch few blocks)
    use std::collections::HashMap;
    let mut cnt: Vec<HashMap<u32, u32>> = vec![HashMap::new(); g.n];
    for (e, &b) in p.assign.iter().enumerate() {
        let (u, v) = g.edges[e];
        *cnt[u as usize].entry(b).or_insert(0) += 1;
        if u != v {
            *cnt[v as usize].entry(b).or_insert(0) += 1;
        }
    }
    // tasks per block for scanning
    let mut tasks_of: Vec<Vec<u32>> = vec![Vec::new(); k];
    for (e, &b) in p.assign.iter().enumerate() {
        tasks_of[b as usize].push(e as u32);
    }
    loop {
        let Some(from) = (0..k).filter(|&b| loads[b] > cap).max_by_key(|&b| loads[b]) else {
            break;
        };
        let fallback = (0..k).filter(|&b| loads[b] < cap).min_by_key(|&b| loads[b]).unwrap();
        // cheapest (task, target) pair: prefer target blocks that already
        // hold one of the task's endpoints (affinity move, delta ≤ 0)
        let mut best: Option<(i64, usize, usize)> = None; // (delta, idx, to)
        for (i, &e) in tasks_of[from].iter().enumerate() {
            if p.assign[e as usize] != from as u32 {
                continue; // stale entry
            }
            let (u, v) = g.edges[e as usize];
            let ends = if u == v { vec![u] } else { vec![u, v] };
            // candidate targets: blocks holding an endpoint, plus fallback
            let mut targets: Vec<usize> = ends
                .iter()
                .flat_map(|&w| cnt[w as usize].keys().copied())
                .map(|b| b as usize)
                .filter(|&b| b != from && loads[b] < cap)
                .collect();
            targets.push(fallback);
            targets.sort_unstable();
            targets.dedup();
            for to in targets {
                let mut delta = 0i64;
                for &w in &ends {
                    let m = &cnt[w as usize];
                    if m.get(&(from as u32)).copied().unwrap_or(0) == 1 {
                        delta -= 1; // w leaves `from` entirely
                    }
                    if m.get(&(to as u32)).copied().unwrap_or(0) == 0 {
                        delta += 1; // w newly appears in `to`
                    }
                }
                if best.is_none_or(|(bd, _, _)| delta < bd) {
                    best = Some((delta, i, to));
                }
            }
            if best.is_some_and(|(bd, _, _)| bd <= -2) {
                break; // cannot do better for a binary task
            }
        }
        let (_, idx, to) = best.expect("overloaded block has tasks and a target");
        let e = tasks_of[from][idx];
        tasks_of[from].swap_remove(idx);
        tasks_of[to].push(e);
        p.assign[e as usize] = to as u32;
        loads[from] -= 1;
        loads[to] += 1;
        let (u, v) = g.edges[e as usize];
        let ends = if u == v { vec![u] } else { vec![u, v] };
        for &w in &ends {
            let m = &mut cnt[w as usize];
            let c = m.get_mut(&(from as u32)).unwrap();
            *c -= 1;
            if *c == 0 {
                m.remove(&(from as u32));
            }
            *m.entry(to as u32).or_insert(0) += 1;
        }
    }
}

/// Auxiliary-edge cut cost of a task-graph partition — the quantity
/// Theorem 1 upper-bounds the reconstructed vertex-cut cost with.
/// Cut accounting runs on the deterministic parallel reduction
/// (`edge_cut_par`), bit-identical to the sequential sum.
pub fn aux_cut_cost(g: &Graph, p: &EdgePartition, chain: ChainOrder, seed: u64) -> u64 {
    let tg = task_graph(g, chain, seed);
    tg.edge_cut_par(&p.assign, 0) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;
    use crate::partition::quality::{balance_factor, vertex_cut_cost};
    use crate::partition::default_sched::default_partition;
    use crate::partition::powergraph;

    #[test]
    fn task_graph_shape() {
        // triangle: 3 tasks; each vertex of degree 2 adds 1 aux edge
        let g = gen::clique(3);
        let tg = task_graph(&g, ChainOrder::Index, 0);
        assert_eq!(tg.n, 3);
        let edge_count: usize = (0..tg.n as u32).map(|v| tg.neighbors(v).count()).sum::<usize>() / 2;
        assert_eq!(edge_count, 3); // 3 vertices × (2−1) aux, all distinct pairs
    }

    #[test]
    fn clone_graph_matches_definition() {
        let g = gen::cfd_mesh(6, 6, 1);
        let (cg, owner) = clone_graph(&g, ChainOrder::Index, 0);
        assert_eq!(cg.n, 2 * g.m()); // 2m clones (Definition 3)
        // every clone owned by a real vertex/edge
        for &(v, e) in &owner {
            assert!((v as usize) < g.n && (e as usize) < g.m());
        }
        // heavy edges: exactly m of them
        let heavy: usize = (0..cg.n as u32)
            .map(|v| cg.neighbors(v).filter(|&(_, w)| w >= ORIG_EDGE_WEIGHT).count())
            .sum::<usize>()
            / 2;
        assert_eq!(heavy, g.m());
    }

    /// Theorem 1: C_ep(D) ≤ aux-edge cut of the vertex partition of D'.
    #[test]
    fn theorem1_invariant_holds() {
        let g = gen::cfd_mesh(12, 12, 3);
        let k = 8;
        let p = partition_edges(&g, k, &EpOpts::default());
        let cep = vertex_cut_cost(&g, &p);
        let aux = aux_cut_cost(&g, &p, ChainOrder::Index, 0);
        assert!(cep <= aux, "C_ep {cep} > aux cut {aux}");
    }

    #[test]
    fn fig3_example_reaches_optimal() {
        // 6-interaction example of Fig 3: EP should find the cost-1 split
        let g = Graph::from_edges(7, vec![(0, 1), (1, 2), (1, 3), (3, 4), (4, 5), (5, 6)]);
        let p = partition_edges(&g, 2, &EpOpts::default());
        assert_eq!(p.loads(), vec![3, 3]);
        assert_eq!(vertex_cut_cost(&g, &p), 1);
    }

    use crate::graph::Graph;

    #[test]
    fn ep_beats_default_and_powergraph_on_mesh() {
        let g = gen::cfd_mesh(24, 24, 7);
        let k = 8;
        let ep = vertex_cut_cost(&g, &partition_edges(&g, k, &EpOpts::default()));
        let def = vertex_cut_cost(&g, &default_partition(g.m(), k));
        let rnd = vertex_cut_cost(&g, &powergraph::random_partition(&g, k, 1));
        let grd = vertex_cut_cost(&g, &powergraph::greedy_partition(&g, k, 1));
        assert!(ep < def, "ep {ep} !< default {def}");
        assert!(ep < rnd, "ep {ep} !< random {rnd}");
        assert!(ep < grd, "ep {ep} !< greedy {grd}");
    }

    #[test]
    fn ep_balance_is_metis_grade() {
        // paper: balance factor typically < 1.03 at UF-collection scale;
        // recursive bisection compounds eps per level, so at this small
        // scale we assert the same order of balance (< 1.10)
        let g = gen::power_law(2000, 3, 11);
        let p = partition_edges(&g, 16, &EpOpts::default());
        let bf = balance_factor(&p);
        assert!(bf < 1.10, "balance factor {bf}");
    }

    #[test]
    fn chain_order_random_is_legal() {
        // the paper: any clone-chaining order is *legal* (correctness);
        // quality may differ (that's the ablation_chain bench)
        let g = gen::cfd_mesh(10, 10, 5);
        let opts = EpOpts { chain: ChainOrder::Random, ..Default::default() };
        let p = partition_edges(&g, 4, &opts);
        assert_eq!(p.assign.len(), g.m());
        assert!(p.assign.iter().all(|&b| b < 4));
        // Theorem 1 still holds for the random chain order
        let cep = vertex_cut_cost(&g, &p);
        let aux = aux_cut_cost(&g, &p, ChainOrder::Random, opts.vp.seed);
        assert!(cep <= aux, "C_ep {cep} > aux {aux}");
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_edges(5, vec![]);
        let p = partition_edges(&g, 4, &EpOpts::default());
        assert!(p.assign.is_empty());
    }

    #[test]
    fn k1_costs_zero() {
        let g = gen::power_law(300, 2, 3);
        let p = partition_edges(&g, 1, &EpOpts::default());
        assert_eq!(vertex_cut_cost(&g, &p), 0);
    }
}
