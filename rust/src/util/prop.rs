//! Tiny property-based-testing harness (proptest is unavailable offline).
//!
//! `check(name, cases, f)` runs `f` against `cases` seeded inputs; on
//! failure it retries the failing seed with progressively "smaller"
//! size hints (a lightweight stand-in for shrinking) and reports the
//! smallest seed/size that still fails, so failures are reproducible by
//! pasting the seed into a unit test.

use super::rng::Pcg32;

/// Size hint handed to generators; property runners shrink this on failure.
#[derive(Clone, Copy, Debug)]
pub struct Gen {
    pub seed: u64,
    pub size: usize,
}

/// Run `prop` for `cases` random cases. `prop` returns Err(msg) on failure.
///
/// Panics with a reproduction line on the first failure (after shrinking
/// the size hint as far as the failure persists).
pub fn check<F>(name: &str, cases: u64, mut prop: F)
where
    F: FnMut(&mut Pcg32, Gen) -> Result<(), String>,
{
    let base_seed = fnv1a(name.as_bytes());
    for case in 0..cases {
        let seed = base_seed ^ (case.wrapping_mul(0x9E3779B97F4A7C15));
        let size = 2 + (case as usize % 64) * 4;
        let g = Gen { seed, size };
        let mut rng = Pcg32::new(seed);
        if let Err(msg) = prop(&mut rng, g) {
            // shrink: halve the size hint while the failure persists
            let mut best = (g, msg);
            let mut size = g.size;
            while size > 1 {
                size /= 2;
                let g2 = Gen { seed, size };
                let mut rng2 = Pcg32::new(seed);
                match prop(&mut rng2, g2) {
                    Err(m) => best = (g2, m),
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{}' failed (seed={:#x}, size={}): {}",
                name, best.0.seed, best.0.size, best.1
            );
        }
    }
}

fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Assert helper returning Err for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("add-commutes", 50, |rng, g| {
            let a = rng.gen_range(g.size.max(1)) as i64;
            let b = rng.gen_range(g.size.max(1)) as i64;
            if a + b == b + a { Ok(()) } else { Err("math broke".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always-fails", 5, |_, _| Err("nope".into()));
    }

    #[test]
    fn deterministic_across_runs() {
        let mut log1 = Vec::new();
        check("det", 10, |rng, _| {
            log1.push(rng.next_u32());
            Ok(())
        });
        let mut log2 = Vec::new();
        check("det", 10, |rng, _| {
            log2.push(rng.next_u32());
            Ok(())
        });
        assert_eq!(log1, log2);
    }
}
