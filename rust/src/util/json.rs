//! Minimal JSON reader/writer for `artifacts/manifest.json` and the
//! `epgraph serve` line protocol.
//!
//! serde is not available offline, and every consumer is one of our own
//! machine-generated formats (aot.py's manifest, the service protocol),
//! so a small recursive-descent parser covering the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null) plus a
//! matching writer (`Json::dump`) and a streaming line decoder
//! (`JsonLines`) are sufficient and keep the runtime dependency-free.

use std::collections::BTreeMap;
use std::fmt;
use std::io::BufRead;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Non-negative integral numbers only (the service protocol's ids and
    /// sizes); anything fractional or negative is None, not truncated.
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= (1u64 << 53) as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    /// Serialize to compact JSON (no whitespace).  Object keys come out
    /// in BTreeMap order, so equal values serialize identically —
    /// protocol responses diff cleanly.  Non-finite numbers become null
    /// (JSON has no NaN/Inf).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() <= (1u64 << 53) as f64 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Streaming JSON-lines decoder: one JSON value per newline-terminated
/// line, blank lines skipped.  The service protocol (`service::proto`)
/// frames every request and response this way, so a reader never needs
/// more lookahead than one line.
pub struct JsonLines<R: BufRead> {
    reader: R,
    buf: String,
    line_no: usize,
}

impl<R: BufRead> JsonLines<R> {
    pub fn new(reader: R) -> Self {
        JsonLines { reader, buf: String::new(), line_no: 0 }
    }

    /// Next value, `Ok(None)` at EOF.  Parse failures surface as
    /// `InvalidData` io errors tagged with the line number.
    pub fn next_value(&mut self) -> std::io::Result<Option<Json>> {
        loop {
            self.buf.clear();
            if self.reader.read_line(&mut self.buf)? == 0 {
                return Ok(None);
            }
            self.line_no += 1;
            let text = self.buf.trim();
            if text.is_empty() {
                continue;
            }
            return match Json::parse(text) {
                Ok(v) => Ok(Some(v)),
                Err(e) => Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("json-lines input, line {}: {e}", self.line_no),
                )),
            };
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, val: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(val)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(key, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{0008}'),
                        Some(b'f') => s.push('\u{000C}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 scalar
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i]).map_err(|_| JsonError {
                        pos: start,
                        msg: "invalid utf-8".into(),
                    })?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let text = r#"{"format": "hlo-text", "version": 1,
            "artifacts": [{"entry": "spmv", "config": "t0", "n_in": 1024,
                           "k": 8, "e": 256, "c": 128, "file": "spmv_t0.hlo.txt"}]}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("format").unwrap().as_str(), Some("hlo-text"));
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts.len(), 1);
        assert_eq!(arts[0].get("n_in").unwrap().as_usize(), Some(1024));
    }

    #[test]
    fn parses_scalars_and_nesting() {
        let j = Json::parse(r#"[1, -2.5, 1e3, true, false, null, "a\nb", {"x": []}]"#).unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[1].as_f64(), Some(-2.5));
        assert_eq!(a[2].as_f64(), Some(1000.0));
        assert_eq!(a[3], Json::Bool(true));
        assert_eq!(a[5], Json::Null);
        assert_eq!(a[6].as_str(), Some("a\nb"));
        assert!(a[7].get("x").unwrap().as_arr().unwrap().is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn dump_roundtrips() {
        let text = r#"{"a":[1,2.5,true,null],"b":"x\ny","c":{"k":-3}}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.dump(), text);
        assert_eq!(Json::parse(&j.dump()).unwrap(), j);
    }

    #[test]
    fn dump_is_key_order_canonical() {
        let a = Json::parse(r#"{"x":1,"y":2}"#).unwrap();
        let b = Json::parse(r#"{"y":2,"x":1}"#).unwrap();
        assert_eq!(a.dump(), b.dump());
    }

    #[test]
    fn as_u64_rejects_fractional_and_negative() {
        assert_eq!(Json::Num(3.0).as_u64(), Some(3));
        assert_eq!(Json::Num(3.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Bool(true).as_u64(), None);
        assert_eq!(Json::Bool(true).as_bool(), Some(true));
    }

    #[test]
    fn json_lines_streams_values_and_skips_blanks() {
        let input = "{\"a\":1}\n\n[1,2]\n{\"b\":2}";
        let mut lines = JsonLines::new(std::io::BufReader::new(input.as_bytes()));
        assert_eq!(lines.next_value().unwrap().unwrap().get("a").unwrap().as_u64(), Some(1));
        assert_eq!(lines.next_value().unwrap().unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(lines.next_value().unwrap().unwrap().get("b").unwrap().as_u64(), Some(2));
        assert!(lines.next_value().unwrap().is_none());
    }

    #[test]
    fn json_lines_reports_bad_line() {
        let mut lines = JsonLines::new(std::io::BufReader::new("{}\nnot json\n".as_bytes()));
        assert!(lines.next_value().unwrap().is_some());
        let err = lines.next_value().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
        assert!(err.to_string().contains("line 2"));
    }
}
