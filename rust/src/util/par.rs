//! Structured parallelism on `std::thread::scope` — the offline stand-in
//! for rayon (the registry is unreachable, so rayon cannot be added; see
//! Cargo.toml).  The API mirrors the rayon shapes the partitioner needs:
//! `join` (= rayon::join), `fill_indexed` / `map_indexed` (= parallel
//! iterator collect), and `chunk_ranges` for manual range splitting.
//!
//! Determinism contract: every helper computes each output cell as a
//! pure function of the inputs and the cell index, so results are
//! bit-identical for every thread count (including 1).  Callers must
//! uphold the same purity in their closures; the partitioner's
//! determinism tests (tests/perf_parity.rs) enforce it end to end.

/// Resolve a thread-count knob: 0 means "one per available core".
pub fn resolve_threads(threads: usize) -> usize {
    if threads > 0 {
        threads
    } else {
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    }
}

/// Below this many items, parallel fills fall back to the sequential
/// loop — thread spawn/synchronization costs more than the work.
pub const PAR_MIN_LEN: usize = 4096;

/// Run two closures, on two threads when `threads > 1` (rayon::join).
pub fn join<A, B, RA, RB>(threads: usize, a: A, b: B) -> (RA, RB)
where
    A: FnOnce() -> RA + Send,
    B: FnOnce() -> RB + Send,
    RA: Send,
    RB: Send,
{
    if threads <= 1 {
        let ra = a();
        let rb = b();
        (ra, rb)
    } else {
        std::thread::scope(|s| {
            let hb = s.spawn(b);
            let ra = a();
            let rb = hb.join().expect("par::join worker panicked");
            (ra, rb)
        })
    }
}

/// Split `0..len` into at most `parts` contiguous, non-empty ranges.
pub fn chunk_ranges(len: usize, parts: usize) -> Vec<(usize, usize)> {
    if len == 0 {
        return Vec::new();
    }
    let parts = parts.max(1).min(len);
    let chunk = len.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    while lo < len {
        let hi = (lo + chunk).min(len);
        out.push((lo, hi));
        lo = hi;
    }
    out
}

/// Overwrite `out[i] = f(i)` for all i, splitting the slice across up to
/// `threads` workers.  `f` must be pure in `i`.
pub fn fill_indexed<T, F>(threads: usize, out: &mut [T], f: F)
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let t = resolve_threads(threads);
    if t <= 1 || out.len() < PAR_MIN_LEN {
        for (i, o) in out.iter_mut().enumerate() {
            *o = f(i);
        }
        return;
    }
    let chunk = out.len().div_ceil(t);
    std::thread::scope(|s| {
        for (ci, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = ci * chunk;
                for (i, o) in slice.iter_mut().enumerate() {
                    *o = f(base + i);
                }
            });
        }
    });
}

/// Collect `(0..n).map(f)` into a Vec, in parallel.  `f` must be pure.
pub fn map_indexed<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send + Clone + Default,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    fill_indexed(threads, &mut out, f);
    out
}

/// Run `n` heavyweight independent tasks on at most `threads` workers
/// and collect their results in task order.  Unlike `fill_indexed` this
/// has no sequential-fallback size threshold — use it for a handful of
/// expensive jobs (GGGP restarts, bisection sides), not for per-element
/// work.  The worker count honors the `threads` budget, so nested use
/// (e.g. under `join`) never oversubscribes the knob.
pub fn run_tasks<T, F>(threads: usize, n: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    let t = resolve_threads(threads).min(n.max(1));
    if t <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let ranges = chunk_ranges(n, t);
    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut rest: &mut [Option<T>] = &mut results;
        for &(lo, hi) in &ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
            rest = tail;
            let f = &f;
            s.spawn(move || {
                for (i, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(lo + i));
                }
            });
        }
    });
    results.into_iter().map(|o| o.expect("par::run_tasks worker panicked")).collect()
}

/// Like `run_tasks`, but hands each worker a private scratch value
/// created once per worker (not per task) — for task batches that want
/// reusable buffers without allocating per task (e.g. GGGP restarts).
/// Determinism contract: `f` must produce the same output for a given
/// task index regardless of scratch history (reset scratch on entry).
pub fn run_tasks_with<T, S, I, F>(threads: usize, n: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let t = resolve_threads(threads).min(n.max(1));
    if t <= 1 || n <= 1 {
        let mut scratch = init();
        return (0..n).map(|i| f(&mut scratch, i)).collect();
    }
    let ranges = chunk_ranges(n, t);
    let mut results: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|s| {
        let mut rest: &mut [Option<T>] = &mut results;
        for &(lo, hi) in &ranges {
            let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(hi - lo);
            rest = tail;
            let init = &init;
            let f = &f;
            s.spawn(move || {
                let mut scratch = init();
                for (i, slot) in chunk.iter_mut().enumerate() {
                    *slot = Some(f(&mut scratch, lo + i));
                }
            });
        }
    });
    results
        .into_iter()
        .map(|o| o.expect("par::run_tasks_with worker panicked"))
        .collect()
}

/// Run `f(lo, hi, worker_index)` over a fixed partition of `0..len`
/// into `parts` ranges, using up to `threads` worker threads.  The
/// partition depends only on `(len, parts)`, so a caller that derives
/// per-range state deterministically gets thread-count-independent
/// results.  `f` must only touch state owned by its range.
pub fn for_ranges<F>(threads: usize, len: usize, parts: usize, f: F)
where
    F: Fn(usize, usize, usize) + Sync,
{
    let ranges = chunk_ranges(len, parts);
    let t = resolve_threads(threads);
    if t <= 1 || ranges.len() <= 1 {
        for (wi, &(lo, hi)) in ranges.iter().enumerate() {
            f(lo, hi, wi);
        }
        return;
    }
    // ranges.len() <= parts is small (callers pass parts ~ threads), so
    // one thread per range is fine.
    std::thread::scope(|s| {
        for (wi, &(lo, hi)) in ranges.iter().enumerate() {
            let f = &f;
            s.spawn(move || f(lo, hi, wi));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn join_returns_both() {
        for t in [1, 4] {
            let (a, b) = join(t, || 1 + 1, || "x".to_string());
            assert_eq!(a, 2);
            assert_eq!(b, "x");
        }
    }

    #[test]
    fn chunk_ranges_cover_exactly() {
        for (len, parts) in [(0, 4), (1, 4), (10, 3), (4096, 8), (7, 100)] {
            let r = chunk_ranges(len, parts);
            let mut expect = 0;
            for &(lo, hi) in &r {
                assert_eq!(lo, expect);
                assert!(hi > lo);
                expect = hi;
            }
            assert_eq!(expect, len);
        }
    }

    #[test]
    fn fill_indexed_matches_sequential_for_all_thread_counts() {
        let n = 10_000;
        let mut seq = vec![0u64; n];
        fill_indexed(1, &mut seq, |i| (i as u64).wrapping_mul(0x9E37));
        for t in [2, 3, 8] {
            let mut par = vec![0u64; n];
            fill_indexed(t, &mut par, |i| (i as u64).wrapping_mul(0x9E37));
            assert_eq!(seq, par, "threads={t}");
        }
    }

    #[test]
    fn map_indexed_small_input() {
        assert_eq!(map_indexed(4, 3, |i| i * 2), vec![0, 2, 4]);
    }

    #[test]
    fn run_tasks_ordered_results() {
        for t in [1, 4] {
            let r = run_tasks(t, 5, |i| i * i);
            assert_eq!(r, vec![0, 1, 4, 9, 16], "threads={t}");
        }
    }

    #[test]
    fn run_tasks_with_matches_plain_run_tasks() {
        // scratch is reset on entry, so results must be identical to the
        // scratch-free path for every thread count
        for t in [1, 3, 8] {
            let r = run_tasks_with(
                t,
                7,
                Vec::<u64>::new,
                |buf, i| {
                    buf.clear();
                    buf.extend(0..=i as u64);
                    buf.iter().sum::<u64>()
                },
            );
            assert_eq!(r, run_tasks(1, 7, |i| (0..=i as u64).sum()), "threads={t}");
        }
    }

    #[test]
    fn for_ranges_visits_every_index_once() {
        use std::sync::Mutex;
        let hits = Mutex::new(vec![0u8; 1000]);
        for_ranges(4, 1000, 4, |lo, hi, _w| {
            let mut h = hits.lock().unwrap();
            for c in &mut h[lo..hi] {
                *c += 1;
            }
        });
        assert!(hits.into_inner().unwrap().iter().all(|&c| c == 1));
    }
}
