//! Dependency-free utilities: PRNG, JSON reader, property-test harness,
//! bench harness.  These exist because the build environment is fully
//! offline (see Cargo.toml note).

pub mod benchkit;
pub mod json;
pub mod par;
pub mod poll;
pub mod prop;
pub mod rng;
