//! Deterministic PRNG (PCG32 + SplitMix64 seeding).
//!
//! The offline environment has no `rand` crate, so the repo carries its
//! own small generator.  Everything that needs randomness (graph
//! generators, PowerGraph-random partitioning, property tests, workload
//! synthesis) takes an explicit seed so runs are reproducible.

/// PCG-XSH-RR 64/32 — O'Neill's PCG32. Small, fast, decent quality.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

/// SplitMix64 — used to stretch one u64 seed into PCG's (state, inc).
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let init_state = splitmix64(&mut s);
        let init_inc = splitmix64(&mut s) | 1;
        let mut rng = Pcg32 { state: 0, inc: init_inc };
        rng.state = rng.state.wrapping_add(init_state);
        rng.next_u32();
        rng
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        let bound = bound as u64;
        let mut m = (self.next_u32() as u64).wrapping_mul(bound);
        let mut lo = m as u32;
        if (lo as u64) < bound {
            let t = bound.wrapping_neg() % bound;
            while (lo as u64) < t {
                m = (self.next_u32() as u64).wrapping_mul(bound);
                lo = m as u32;
            }
        }
        (m >> 32) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(1e-12);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.gen_range(i + 1);
            slice.swap(i, j);
        }
    }

    /// Pareto-tail sample via inverse CDF; returns values in [1, max].
    /// Drives the power-law degree generators (in-2004 / scircuit style).
    pub fn gen_pareto(&mut self, alpha: f64, max: usize) -> usize {
        let u = self.gen_f64().max(1e-12);
        let v = (1.0 / u).powf(1.0 / alpha);
        (v.floor() as usize).clamp(1, max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let a: Vec<u32> = { let mut r = Pcg32::new(7); (0..8).map(|_| r.next_u32()).collect() };
        let b: Vec<u32> = { let mut r = Pcg32::new(7); (0..8).map(|_| r.next_u32()).collect() };
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        assert_ne!(
            (0..4).map(|_| a.next_u32()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u32()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn gen_range_in_bounds_and_covers() {
        let mut r = Pcg32::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all buckets hit in 1000 draws");
    }

    #[test]
    fn gen_f64_unit_interval_mean() {
        let mut r = Pcg32::new(11);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.gen_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean} far from 0.5");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::new(5);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn pareto_bounds() {
        let mut r = Pcg32::new(9);
        for _ in 0..1000 {
            let v = r.gen_pareto(2.1, 50);
            assert!((1..=50).contains(&v));
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut r = Pcg32::new(17);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
