//! In-repo micro/macro benchmark harness (criterion is unavailable
//! offline).  Used by the `benches/*.rs` targets (harness = false).
//!
//! Methodology: warmup runs, then `iters` timed runs; reports min /
//! median / mean / p95 wall-clock.  Black-box via `std::hint::black_box`.
//! Good enough for the paper's comparisons, which span 2x–1000x gaps.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl Stats {
    pub fn row(&self) -> String {
        format!(
            "{:<42} iters={:<4} min={:>12?} median={:>12?} mean={:>12?} p95={:>12?}",
            self.name, self.iters, self.min, self.median, self.mean, self.p95
        )
    }
}

/// Time `f` (which should return something to black-box) `iters` times
/// after `warmup` untimed runs.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let n = times.len();
    let mean = times.iter().sum::<Duration>() / n as u32;
    Stats {
        name: name.to_string(),
        iters: n,
        min: times[0],
        median: times[n / 2],
        mean,
        p95: times[(n * 95 / 100).min(n - 1)],
    }
}

/// Time one run of `f` — for long macro-benchmarks where a single
/// measurement is the right granularity (the paper reports totals).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Simple fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{:<width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers, &self.widths);
        println!(
            "{}",
            self.widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>()
        );
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Tiny JSON object writer for machine-readable bench baselines
/// (serde is unavailable offline; values are flat key/value pairs plus
/// optional pre-encoded nested objects via `raw`).  Keys are emitted in
/// insertion order so baselines diff cleanly across runs.
#[derive(Debug, Default)]
pub struct JsonReport {
    fields: Vec<(String, String)>,
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl JsonReport {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn str(&mut self, key: &str, v: &str) -> &mut Self {
        self.fields.push((key.to_string(), format!("\"{}\"", json_escape(v))));
        self
    }

    pub fn int(&mut self, key: &str, v: u64) -> &mut Self {
        self.fields.push((key.to_string(), v.to_string()));
        self
    }

    pub fn num(&mut self, key: &str, v: f64) -> &mut Self {
        // JSON has no NaN/Inf; clamp to null for robustness
        let enc = if v.is_finite() { format!("{v:.6}") } else { "null".to_string() };
        self.fields.push((key.to_string(), enc));
        self
    }

    /// Insert a pre-encoded JSON value (nested object/array).
    pub fn raw(&mut self, key: &str, v: &str) -> &mut Self {
        self.fields.push((key.to_string(), v.to_string()));
        self
    }

    pub fn render(&self) -> String {
        let mut s = String::from("{\n");
        for (i, (k, v)) in self.fields.iter().enumerate() {
            s.push_str(&format!("  \"{}\": {}", json_escape(k), v));
            if i + 1 < self.fields.len() {
                s.push(',');
            }
            s.push('\n');
        }
        s.push('}');
        s.push('\n');
        s
    }

    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

// ---------------------------------------------------------- regression gate

/// Ratio-style headline metrics tracked by the CI bench-regression gate.
/// Wall-clock seconds are machine-dependent, so only relative measures
/// (speedups over the in-run reference pipeline, cut-quality ratios)
/// are gated — they are stable across runner hardware.
const GATED_METRICS: &[(&str, bool)] = &[
    // (key, higher_is_better)
    ("speedup_single_thread", true),
    ("speedup_multi_thread", true),
    ("cut_ratio_new_over_ref", false),
    ("kway_refine_speedup", true),
    ("kway_cut_ratio_new_over_ref", false),
    // pipelined hit-path throughput over the in-run thread-per-connection
    // baseline (benches/service.rs) — the PR 7 reactor headline
    ("serve_pipelined_speedup", true),
    // forwarded-hit latency over owned-hit latency in a two-node fleet
    // (benches/service.rs) — a ratio of in-run measurements, so stable
    // across runner hardware; gated as a ceiling (lower is better)
    ("forwarded_hit_overhead", false),
    // incremental re-partitioning on a ≤1% edge delta vs a cold full
    // re-optimization (benches/partition.rs, PR 9): wall-clock speedup
    // floor and cut-quality ceiling of the warm-started refinement
    ("delta_refine_speedup", true),
    ("delta_cut_ratio", false),
    // data-parallel LP engines vs the FM quality reference on a cold
    // k=64 partition (benches/partition.rs, PR 10): wall-clock speedup
    // floor of Mode::Lp and a ceiling on its cut relative to FM
    ("lp_speedup", true),
    ("lp_cut_ratio", false),
];

/// Compare a freshly produced bench baseline (`current`, JSON text)
/// against a committed one (`baseline`).  A metric regresses when it is
/// worse than the baseline by more than `tol` (relative, e.g. 0.25 =
/// 25%).  Metrics absent from either side are reported but never fail
/// (so baselines roll forward cleanly when fields are added), and
/// mismatched `mode` fields (smoke vs full) skip gating entirely —
/// the numbers would not be comparable.
///
/// Returns the per-metric report lines, or Err with the regression
/// summary (also containing the report) when the gate fails.
pub fn compare_baselines(baseline: &str, current: &str, tol: f64) -> Result<Vec<String>, String> {
    use crate::util::json::Json;
    let base = Json::parse(baseline).map_err(|e| format!("baseline JSON: {e}"))?;
    let cur = Json::parse(current).map_err(|e| format!("current JSON: {e}"))?;
    let mode = |j: &Json| j.get("mode").and_then(|m| m.as_str().map(str::to_string));
    let (bm, cm) = (mode(&base), mode(&cur));
    if bm != cm {
        return Ok(vec![format!(
            "mode mismatch (baseline {bm:?}, current {cm:?}) — gate skipped",
        )]);
    }
    let mut lines = Vec::new();
    let mut failures = Vec::new();
    for &(key, higher_better) in GATED_METRICS {
        let b = base.get(key).and_then(|j| j.as_f64());
        let c = cur.get(key).and_then(|j| j.as_f64());
        let (b, c) = match (b, c) {
            (Some(b), Some(c)) => (b, c),
            _ => {
                lines.push(format!("{key}: missing on one side (base {b:?}, cur {c:?}) — skipped"));
                continue;
            }
        };
        let ok = if higher_better { c >= b * (1.0 - tol) } else { c <= b * (1.0 + tol) };
        let delta = if b != 0.0 { (c - b) / b * 100.0 } else { 0.0 };
        let verdict = if ok { "ok" } else { "REGRESSED" };
        lines.push(format!("{key}: base {b:.4} cur {c:.4} ({delta:+.1}%) {verdict}"));
        if !ok {
            failures.push(key);
        }
    }
    if failures.is_empty() {
        Ok(lines)
    } else {
        Err(format!(
            "bench regression beyond {:.0}% tolerance in: {}\n{}",
            tol * 100.0,
            failures.join(", "),
            lines.join("\n")
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_report_renders_and_parses() {
        let mut r = JsonReport::new();
        r.str("bench", "partition").int("m", 1000000).num("speedup", 3.25);
        r.raw("graph", "{\"n\": 5}");
        let text = r.render();
        let parsed = crate::util::json::Json::parse(&text).expect("valid json");
        assert_eq!(parsed.get("bench").and_then(|j| j.as_str()), Some("partition"));
        assert!(parsed.get("graph").is_some());
    }

    #[test]
    fn json_escape_control_chars() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
    }

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop", 2, 16, || 1 + 1);
        assert_eq!(s.iters, 16);
        assert!(s.min <= s.median && s.median <= s.p95);
    }

    #[test]
    fn table_accepts_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }

    #[test]
    #[should_panic(expected = "table row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    fn baseline_json(s1: f64, cut_ratio: f64) -> String {
        let mut r = JsonReport::new();
        r.str("bench", "partition")
            .str("mode", "smoke")
            .num("speedup_single_thread", s1)
            .num("cut_ratio_new_over_ref", cut_ratio);
        r.render()
    }

    #[test]
    fn compare_passes_within_tolerance() {
        let base = baseline_json(3.0, 1.00);
        let cur = baseline_json(2.6, 1.05); // −13% speedup, +5% cut
        let lines = compare_baselines(&base, &cur, 0.25).expect("within 25%");
        assert!(lines.iter().any(|l| l.contains("speedup_single_thread") && l.ends_with("ok")));
    }

    #[test]
    fn compare_fails_beyond_tolerance() {
        let base = baseline_json(3.0, 1.00);
        let cur = baseline_json(2.0, 1.00); // −33% speedup
        let err = compare_baselines(&base, &cur, 0.25).unwrap_err();
        assert!(err.contains("speedup_single_thread"), "{err}");
    }

    #[test]
    fn compare_fails_on_quality_regression() {
        // lower-is-better metric: cut ratio growing 30% must fail
        let base = baseline_json(3.0, 1.00);
        let cur = baseline_json(3.0, 1.30);
        let err = compare_baselines(&base, &cur, 0.25).unwrap_err();
        assert!(err.contains("cut_ratio_new_over_ref"), "{err}");
    }

    #[test]
    fn forwarded_hit_overhead_gates_as_a_ceiling() {
        let report = |overhead: f64| {
            let mut r = JsonReport::new();
            r.str("mode", "smoke").num("forwarded_hit_overhead", overhead);
            r.render()
        };
        // shrinking overhead (cheaper forwarding) always passes
        let lines = compare_baselines(&report(8.0), &report(2.0), 0.25).expect("improvement ok");
        assert!(lines.iter().any(|l| l.contains("forwarded_hit_overhead") && l.ends_with("ok")));
        // growing past the ceiling fails
        let err = compare_baselines(&report(8.0), &report(11.0), 0.25).unwrap_err();
        assert!(err.contains("forwarded_hit_overhead"), "{err}");
    }

    #[test]
    fn lp_gate_floors_speedup_and_ceilings_cut_ratio() {
        let report = |speedup: f64, ratio: f64| {
            let mut r = JsonReport::new();
            r.str("mode", "smoke").num("lp_speedup", speedup).num("lp_cut_ratio", ratio);
            r.render()
        };
        // faster AND no worse on quality passes
        let lines = compare_baselines(&report(3.0, 1.15), &report(5.0, 1.02), 0.25)
            .expect("improvement ok");
        assert!(lines.iter().any(|l| l.contains("lp_speedup") && l.ends_with("ok")));
        // the speedup is a floor: dropping far below it fails
        let err = compare_baselines(&report(3.0, 1.15), &report(1.5, 1.10), 0.25).unwrap_err();
        assert!(err.contains("lp_speedup"), "{err}");
        // the cut ratio is a ceiling: a faster-but-much-worse LP fails
        let err = compare_baselines(&report(3.0, 1.15), &report(9.0, 1.60), 0.25).unwrap_err();
        assert!(err.contains("lp_cut_ratio"), "{err}");
    }

    #[test]
    fn compare_skips_missing_metrics_and_mode_mismatch() {
        let base = baseline_json(3.0, 1.00);
        let mut r = JsonReport::new();
        r.str("mode", "smoke").num("speedup_single_thread", 3.1);
        let lines = compare_baselines(&base, &r.render(), 0.25).expect("missing keys skip");
        assert!(lines.iter().any(|l| l.contains("cut_ratio_new_over_ref") && l.contains("skipped")));

        let mut full = JsonReport::new();
        full.str("mode", "full").num("speedup_single_thread", 0.1);
        let lines = compare_baselines(&base, &full.render(), 0.25).expect("mode mismatch skips");
        assert_eq!(lines.len(), 1);
        assert!(lines[0].contains("gate skipped"));
    }
}
