//! In-repo micro/macro benchmark harness (criterion is unavailable
//! offline).  Used by the `benches/*.rs` targets (harness = false).
//!
//! Methodology: warmup runs, then `iters` timed runs; reports min /
//! median / mean / p95 wall-clock.  Black-box via `std::hint::black_box`.
//! Good enough for the paper's comparisons, which span 2x–1000x gaps.

use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl Stats {
    pub fn row(&self) -> String {
        format!(
            "{:<42} iters={:<4} min={:>12?} median={:>12?} mean={:>12?} p95={:>12?}",
            self.name, self.iters, self.min, self.median, self.mean, self.p95
        )
    }
}

/// Time `f` (which should return something to black-box) `iters` times
/// after `warmup` untimed runs.
pub fn bench<T, F: FnMut() -> T>(name: &str, warmup: usize, iters: usize, mut f: F) -> Stats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times: Vec<Duration> = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed());
    }
    times.sort_unstable();
    let n = times.len();
    let mean = times.iter().sum::<Duration>() / n as u32;
    Stats {
        name: name.to_string(),
        iters: n,
        min: times[0],
        median: times[n / 2],
        mean,
        p95: times[(n * 95 / 100).min(n - 1)],
    }
}

/// Time one run of `f` — for long macro-benchmarks where a single
/// measurement is the right granularity (the paper reports totals).
pub fn time_once<T, F: FnOnce() -> T>(f: F) -> (T, Duration) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed())
}

/// Simple fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            widths: headers.iter().map(|h| h.len()).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(widths) {
                s.push_str(&format!("{:<width$}  ", c, width = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers, &self.widths);
        println!(
            "{}",
            self.widths.iter().map(|w| "-".repeat(*w + 2)).collect::<String>()
        );
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let s = bench("noop", 2, 16, || 1 + 1);
        assert_eq!(s.iters, 16);
        assert!(s.min <= s.median && s.median <= s.p95);
    }

    #[test]
    fn table_accepts_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print(); // smoke: no panic
    }

    #[test]
    #[should_panic(expected = "table row arity")]
    fn table_rejects_bad_arity() {
        let mut t = Table::new(&["a"]);
        t.row(&["1".into(), "2".into()]);
    }
}
