//! Std-only reactor primitives: a tiny slab allocator, a condvar-backed
//! ready-queue, and an adaptive idle backoff.
//!
//! `util::poll` mirrors the shape of `mio` the way `util::par` mirrors
//! `rayon`: the smallest deterministic, dependency-free subset that the
//! rest of the crate needs. We do not wrap `epoll`/`kqueue` — readiness is
//! discovered by *attempting* nonblocking I/O and treating `WouldBlock` as
//! "not ready". That costs one failed syscall per idle socket per sweep,
//! which is amortised by [`IdleBackoff`]: a reactor that made no progress
//! sleeps on its completion [`ReadyQueue`] with an exponentially growing
//! timeout, so worker-pool completions wake it instantly while socket
//! activity is discovered within the backoff ceiling (single-digit
//! milliseconds).
//!
//! The pieces:
//!
//! - [`Token`]: a stable handle into a [`Slab`].
//! - [`Slab`]: index-stable storage for connection state; freed slots are
//!   recycled so tokens stay dense at high churn.
//! - [`ReadyQueue`]: an MPSC-ish queue (any thread pushes, the reactor
//!   drains) with a condvar so the consumer can park cheaply.
//! - [`IdleBackoff`]: exponential poll-interval control.
//! - [`would_block`] / [`interrupted`]: `io::Error` classifiers so reactor
//!   loops read as prose.

use std::collections::VecDeque;
use std::io;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Stable handle for an entry in a [`Slab`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Token(pub usize);

/// Index-stable storage with O(1) insert/remove and slot recycling.
///
/// Unlike `Vec` removal, removing an entry never moves the others, so a
/// `Token` handed out at insert time stays valid until that entry is
/// removed. Freed slots are reused LIFO, keeping indices dense under
/// connection churn.
pub struct Slab<T> {
    entries: Vec<Option<T>>,
    free: Vec<usize>,
    len: usize,
}

impl<T> Default for Slab<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> Slab<T> {
    pub fn new() -> Self {
        Slab { entries: Vec::new(), free: Vec::new(), len: 0 }
    }

    /// Number of live entries.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn insert(&mut self, value: T) -> Token {
        self.len += 1;
        match self.free.pop() {
            Some(idx) => {
                self.entries[idx] = Some(value);
                Token(idx)
            }
            None => {
                self.entries.push(Some(value));
                Token(self.entries.len() - 1)
            }
        }
    }

    pub fn remove(&mut self, token: Token) -> Option<T> {
        let slot = self.entries.get_mut(token.0)?;
        let value = slot.take()?;
        self.free.push(token.0);
        self.len -= 1;
        Some(value)
    }

    pub fn get(&self, token: Token) -> Option<&T> {
        self.entries.get(token.0).and_then(|s| s.as_ref())
    }

    pub fn get_mut(&mut self, token: Token) -> Option<&mut T> {
        self.entries.get_mut(token.0).and_then(|s| s.as_mut())
    }

    /// Collect the tokens of all live entries into `out` (cleared first).
    ///
    /// Reactor sweeps snapshot tokens up front so entries can be removed
    /// mid-iteration; passing a scratch `Vec` avoids a fresh allocation per
    /// sweep at high connection counts.
    pub fn tokens_into(&self, out: &mut Vec<Token>) {
        out.clear();
        for (idx, slot) in self.entries.iter().enumerate() {
            if slot.is_some() {
                out.push(Token(idx));
            }
        }
    }
}

/// A condvar-backed queue: producers push from any thread, one consumer
/// drains. Doubles as the reactor's parking spot — `wait_timeout` returns
/// immediately if anything is queued, so a push between drain and park is
/// never missed.
pub struct ReadyQueue<T> {
    queue: Mutex<VecDeque<T>>,
    ready: Condvar,
}

impl<T> Default for ReadyQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> ReadyQueue<T> {
    pub fn new() -> Self {
        ReadyQueue { queue: Mutex::new(VecDeque::new()), ready: Condvar::new() }
    }

    pub fn push(&self, value: T) {
        let mut q = self.queue.lock().unwrap();
        q.push_back(value);
        drop(q);
        self.ready.notify_one();
    }

    /// Move everything queued into `out` (appended; `out` is not cleared).
    pub fn drain_into(&self, out: &mut Vec<T>) {
        let mut q = self.queue.lock().unwrap();
        out.extend(q.drain(..));
    }

    pub fn len(&self) -> usize {
        self.queue.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.lock().unwrap().is_empty()
    }

    /// Park the calling thread until something is queued or `timeout`
    /// elapses. Returns `true` if the queue is non-empty on return. The
    /// emptiness check happens under the queue lock, so a concurrent
    /// `push` cannot slip between the check and the park.
    pub fn wait_timeout(&self, timeout: Duration) -> bool {
        let q = self.queue.lock().unwrap();
        if !q.is_empty() {
            return true;
        }
        let (q, _) = self.ready.wait_timeout(q, timeout).unwrap();
        !q.is_empty()
    }
}

/// Exponential idle backoff for a polling loop: starts at `min`, doubles
/// after every fruitless sweep up to `max`, and resets to `min` on
/// progress. Keeps a busy reactor hot (sub-millisecond latency) without
/// burning a core when every socket is quiet.
pub struct IdleBackoff {
    current: Duration,
    min: Duration,
    max: Duration,
}

impl IdleBackoff {
    pub fn new(min: Duration, max: Duration) -> Self {
        IdleBackoff { current: min, min, max }
    }

    /// The timeout to sleep for now; doubles the next one (clamped to max).
    pub fn next(&mut self) -> Duration {
        let out = self.current;
        self.current = (self.current * 2).min(self.max);
        out
    }

    pub fn reset(&mut self) {
        self.current = self.min;
    }
}

/// True if this error means "the socket is not ready" rather than broken.
pub fn would_block(err: &io::Error) -> bool {
    err.kind() == io::ErrorKind::WouldBlock
}

/// True if the syscall was interrupted and should simply be retried.
pub fn interrupted(err: &io::Error) -> bool {
    err.kind() == io::ErrorKind::Interrupted
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn slab_insert_remove_recycles_slots() {
        let mut slab: Slab<&str> = Slab::new();
        let a = slab.insert("a");
        let b = slab.insert("b");
        assert_eq!(slab.len(), 2);
        assert_eq!(slab.get(a), Some(&"a"));
        assert_eq!(slab.remove(a), Some("a"));
        assert_eq!(slab.remove(a), None, "double remove is a no-op");
        assert_eq!(slab.len(), 1);
        // The freed slot is reused, and the old token does not alias the
        // new entry's value through `remove` side effects.
        let c = slab.insert("c");
        assert_eq!(c, a, "freed slot is recycled LIFO");
        assert_eq!(slab.get(c), Some(&"c"));
        assert_eq!(slab.get(b), Some(&"b"));
        let mut toks = Vec::new();
        slab.tokens_into(&mut toks);
        toks.sort_by_key(|t| t.0);
        assert_eq!(toks, vec![c, b]);
    }

    #[test]
    fn slab_get_mut_and_stability_under_removal() {
        let mut slab: Slab<u32> = Slab::new();
        let toks: Vec<Token> = (0..8).map(|i| slab.insert(i)).collect();
        slab.remove(toks[3]);
        slab.remove(toks[5]);
        // Remaining tokens still resolve to their original values.
        for (i, &t) in toks.iter().enumerate() {
            if i == 3 || i == 5 {
                assert!(slab.get(t).is_none());
            } else {
                assert_eq!(slab.get(t), Some(&(i as u32)));
                *slab.get_mut(t).unwrap() += 100;
                assert_eq!(slab.get(t), Some(&(i as u32 + 100)));
            }
        }
        assert_eq!(slab.len(), 6);
    }

    #[test]
    fn ready_queue_push_drain_preserves_order() {
        let q: ReadyQueue<u32> = ReadyQueue::new();
        q.push(1);
        q.push(2);
        q.push(3);
        assert_eq!(q.len(), 3);
        let mut out = vec![0u32];
        q.drain_into(&mut out);
        assert_eq!(out, vec![0, 1, 2, 3], "drain appends in FIFO order");
        assert!(q.is_empty());
    }

    #[test]
    fn ready_queue_wait_returns_immediately_when_nonempty() {
        let q: ReadyQueue<u32> = ReadyQueue::new();
        q.push(7);
        let t0 = Instant::now();
        assert!(q.wait_timeout(Duration::from_secs(5)));
        assert!(t0.elapsed() < Duration::from_secs(1), "no park when data is queued");
    }

    #[test]
    fn ready_queue_wakes_parked_consumer_on_push() {
        let q: Arc<ReadyQueue<u32>> = Arc::new(ReadyQueue::new());
        let q2 = q.clone();
        let producer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            q2.push(42);
        });
        let woke = q.wait_timeout(Duration::from_secs(10));
        producer.join().unwrap();
        assert!(woke, "push must wake a parked consumer");
        let mut out = Vec::new();
        q.drain_into(&mut out);
        assert_eq!(out, vec![42]);
    }

    #[test]
    fn ready_queue_wait_times_out_when_idle() {
        let q: ReadyQueue<u32> = ReadyQueue::new();
        assert!(!q.wait_timeout(Duration::from_millis(5)));
    }

    #[test]
    fn idle_backoff_doubles_and_resets() {
        let mut b = IdleBackoff::new(Duration::from_micros(200), Duration::from_millis(5));
        assert_eq!(b.next(), Duration::from_micros(200));
        assert_eq!(b.next(), Duration::from_micros(400));
        assert_eq!(b.next(), Duration::from_micros(800));
        for _ in 0..16 {
            b.next();
        }
        assert_eq!(b.next(), Duration::from_millis(5), "clamped at max");
        b.reset();
        assert_eq!(b.next(), Duration::from_micros(200));
    }

    #[test]
    fn error_classifiers() {
        assert!(would_block(&io::Error::new(io::ErrorKind::WouldBlock, "wb")));
        assert!(!would_block(&io::Error::new(io::ErrorKind::BrokenPipe, "bp")));
        assert!(interrupted(&io::Error::new(io::ErrorKind::Interrupted, "intr")));
        assert!(!interrupted(&io::Error::new(io::ErrorKind::WouldBlock, "wb")));
    }
}
