//! Rodinia-like application workload generators (paper Table 1, §5.3).
//!
//! The paper evaluates six Rodinia applications; it consumes each one
//! *only through its data-affinity graph* (plus a preferred cache type
//! and the block sizes swept in Fig 13).  Each generator here emits the
//! access structure the paper describes for that app — see the per-app
//! doc comments for the mapping argument.

use crate::graph::{gen, Graph};
use crate::util::rng::Pcg32;

/// Which first-level cache the paper uses for the app (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CacheType {
    Software,
    Texture,
}

/// One application workload: a data-affinity graph plus metadata.
#[derive(Clone, Debug)]
pub struct AppWorkload {
    pub name: &'static str,
    pub graph: Graph,
    /// Thread-block sizes swept in Fig 13 for this app.
    pub block_sizes: Vec<usize>,
    /// Cache the paper targets for the app (Table 1).
    pub cache: CacheType,
    /// Times the kernel is (re-)launched — drives the async-optimization
    /// overlap in the coordinator (kernels in loops amortize partition
    /// cost; single-launch kernels need kernel splitting).
    pub kernel_launches: usize,
}

/// b+tree: one-million-record database queries.  Data objects are tree
/// nodes; every query walks root→leaf, so tasks pair consecutive path
/// nodes.  The root/top levels are shared by *all* queries (massive
/// reuse), leaves barely shared.
pub fn btree(queries: usize, fanout: usize, depth: usize, seed: u64) -> AppWorkload {
    let mut rng = Pcg32::new(seed);
    // node ids level by level: level l has fanout^l nodes
    let mut level_base = vec![0usize; depth + 1];
    let mut total = 0usize;
    for l in 0..=depth {
        level_base[l] = total;
        total += fanout.pow(l as u32);
    }
    let mut edges = Vec::with_capacity(queries * depth);
    for _ in 0..queries {
        let mut idx = 0usize; // position within level
        for l in 0..depth {
            let child = idx * fanout + rng.gen_range(fanout);
            let a = (level_base[l] + idx) as u32;
            let b = (level_base[l + 1] + child) as u32;
            edges.push((a, b));
            idx = child;
        }
    }
    AppWorkload {
        name: "b+tree",
        graph: Graph::from_edges(total, edges),
        block_sizes: vec![128, 256, 384, 512],
        cache: CacheType::Software,
        kernel_launches: 16,
    }
}

/// bfs: frontier expansion over a million-node graph — tasks are edge
/// relaxations (frontier vertex, neighbour).  Texture cache in Table 1.
pub fn bfs(n: usize, seed: u64) -> AppWorkload {
    let g = gen::power_law(n, 4, seed);
    AppWorkload {
        name: "bfs",
        graph: g,
        block_sizes: vec![128, 256, 384, 512],
        cache: CacheType::Texture,
        kernel_launches: 24, // one launch per BFS level, typical diameters
    }
}

/// cfd: particle-interaction mesh (Fig 1) — tasks are pairwise
/// interactions on an unstructured mesh with ≤ 4 neighbours.
pub fn cfd(side: usize, seed: u64) -> AppWorkload {
    AppWorkload {
        name: "cfd",
        graph: gen::cfd_mesh(side, side, seed),
        block_sizes: vec![128, 256, 384, 512],
        cache: CacheType::Software,
        kernel_launches: 2000, // time-stepping solver
    }
}

/// gaussian: elimination on a 1024-unknown system.  In step k every
/// remaining row i is updated against pivot row k: tasks pair (pivot
/// segment, row segment) — a sequence of stars with shrinking width.
/// We subsample steps to keep the task count laptop-sized.
pub fn gaussian(n: usize, steps: usize, seed: u64) -> AppWorkload {
    let mut rng = Pcg32::new(seed);
    let mut edges = Vec::new();
    for s in 0..steps {
        let pivot = (s * n / steps).min(n - 2);
        for i in (pivot + 1)..n {
            // row i reads pivot row; we also sample the paired column
            // object to keep tasks binary (matrix is segmented by row)
            edges.push((pivot as u32, i as u32));
            if rng.gen_f64() < 0.25 {
                // occasional cross-row reuse via the multiplier column
                let j = pivot + 1 + rng.gen_range(n - pivot - 1);
                edges.push((i as u32, j as u32));
            }
        }
    }
    AppWorkload {
        name: "gaussian",
        graph: Graph::from_edges(n, edges),
        // gaussian only allows square block sizes in the paper
        block_sizes: vec![16, 64, 256],
        cache: CacheType::Software,
        kernel_launches: steps.max(1),
    }
}

/// particlefilter: SMC tracking of 1000 particles — resampling pairs
/// each particle with a sampled ancestor (degree concentrated on a few
/// heavy ancestors), plus likelihood tasks against a shared template.
pub fn particlefilter(particles: usize, seed: u64) -> AppWorkload {
    let mut rng = Pcg32::new(seed);
    let template = particles as u32; // one shared measurement object
    let mut edges = Vec::with_capacity(2 * particles);
    for i in 0..particles {
        // likelihood: particle vs shared template
        edges.push((i as u32, template));
        // resampling: particle vs ancestor (weight-skewed)
        let anc = rng.gen_pareto(1.3, particles) - 1;
        if anc != i {
            edges.push((i as u32, anc as u32));
        }
    }
    AppWorkload {
        name: "particlefilter",
        graph: Graph::from_edges(particles + 1, edges),
        block_sizes: vec![128, 256, 384, 512],
        cache: CacheType::Software,
        kernel_launches: 64, // one per tracked frame
    }
}

/// streamcluster: 65 536 points, each compared to the current candidate
/// center — a near-star graph, average degree ≤ 2 (the paper's low-reuse
/// case where EP gains little).
pub fn streamcluster(points: usize, centers: usize, seed: u64) -> AppWorkload {
    let mut rng = Pcg32::new(seed);
    let mut edges = Vec::with_capacity(points);
    for i in 0..points {
        let c = points + rng.gen_range(centers.max(1));
        edges.push((i as u32, c as u32));
    }
    AppWorkload {
        name: "streamcluster",
        graph: Graph::from_edges(points + centers, edges),
        block_sizes: vec![128, 256, 384, 512, 1024],
        cache: CacheType::Software,
        kernel_launches: 32,
    }
}

/// The six-application suite of Table 1 at laptop scale.
pub fn rodinia_suite(seed: u64) -> Vec<AppWorkload> {
    vec![
        btree(3000, 8, 4, seed),
        bfs(12000, seed + 1),
        cfd(110, seed + 2),
        gaussian(512, 24, seed + 3),
        particlefilter(4000, seed + 4),
        streamcluster(16384, 12, seed + 5),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn suite_has_six_apps_with_valid_graphs() {
        let suite = rodinia_suite(42);
        assert_eq!(suite.len(), 6);
        for app in &suite {
            app.graph.validate().unwrap();
            assert!(app.graph.m() > 1000, "{} too small", app.name);
            assert!(!app.block_sizes.is_empty());
        }
    }

    #[test]
    fn btree_root_is_hottest() {
        let app = btree(2000, 8, 4, 1);
        // the root (node 0) is touched by every query
        assert_eq!(app.graph.degree(0), 2000);
    }

    #[test]
    fn streamcluster_low_reuse() {
        let app = streamcluster(8192, 8, 2);
        // paper: average degree ≤ 2 → below the reuse threshold
        assert!(app.graph.avg_degree() <= 2.01, "{}", app.graph.avg_degree());
        assert!(!stats::has_enough_reuse(&app.graph, 2.1));
    }

    #[test]
    fn cfd_has_reuse() {
        let app = cfd(60, 3);
        assert!(stats::has_enough_reuse(&app.graph, 2.1));
        assert!(app.graph.max_degree() <= 8);
    }

    #[test]
    fn gaussian_square_blocks_only() {
        let app = gaussian(256, 8, 4);
        for b in &app.block_sizes {
            let s = (*b as f64).sqrt() as usize;
            assert_eq!(s * s, *b, "block size {b} not square");
        }
    }

    #[test]
    fn deterministic() {
        let a = bfs(3000, 7);
        let b = bfs(3000, 7);
        assert_eq!(a.graph.edges, b.graph.edges);
    }
}
