//! epgraph CLI — leader entrypoint.
//!
//! Subcommands (no clap offline; a small hand parser):
//!   epgraph partition --matrix <name|file.mtx> [--k N] [--method M] [--seed S]
//!   epgraph cg        --matrix <name|poisson:side> [--block N] [--iters N] [--wait]
//!   epgraph simulate  --app <name> [--block N]
//!   epgraph bench     <fig4|fig6|table2|fig10|fig11|fig12|table3|fig13|fig14|fig15|ablation|scaling|all>
//!   epgraph artifacts [--outdir DIR] [--configs t0,s1,m1]
//!   epgraph serve     [--port N] [--threads N] [--queue-cap N] [--cache-mb N] [--shards N]
//!                     [--snapshot PATH] [--snapshot-every N] [--snapshot-keep K]
//!                     [--snapshot-interval SECS] [--no-degrade] [--chaos SPEC]
//!                     [--matrix-dir DIR] [--peers HOST:PORT,HOST:PORT,...]
//!   epgraph client    [--addr HOST:PORT | --cluster HOST:PORT,...]
//!                     [--op optimize|stats|health|shutdown]
//!                     [--gen SPEC | --matrix NAME]
//!                     [--base FINGERPRINT --delta-add u:v,... --delta-remove u:v,...]
//!                     [--k N] [--seed S] [--mode fm|lp] [--repeat N] [--concurrency N] [--verify]
//!                     [--pipeline N] [--deadline-ms N] [--max-retries N]
//!                     [--retry-budget-ms N]
//!   epgraph info

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use epgraph::coordinator::{run_cg, CgRunConfig};
use epgraph::experiments as exp;
use epgraph::gpusim::GpuConfig;
use epgraph::partition::{quality, Method};
use epgraph::runtime::{default_artifacts_dir, Engine};
use epgraph::sparse::{gen, matrix_market, Coo};
use epgraph::util::rng::Pcg32;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(args[i].clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn get_usize(flags: &HashMap<String, String>, key: &str, default: usize) -> usize {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

/// Parse a `--delta-add`/`--delta-remove` edge list: comma-separated
/// `u:v` pairs (`"3:17,4:9"`).  Absent flag means an empty side.
fn parse_edge_pairs(spec: Option<&str>) -> Result<Vec<(u32, u32)>> {
    let Some(spec) = spec else { return Ok(Vec::new()) };
    spec.split(',')
        .map(str::trim)
        .filter(|s| !s.is_empty())
        .map(|s| {
            let (u, v) = s
                .split_once(':')
                .ok_or_else(|| anyhow!("edge '{s}' is not of the form u:v"))?;
            Ok((
                u.trim().parse().map_err(|_| anyhow!("bad endpoint in '{s}'"))?,
                v.trim().parse().map_err(|_| anyhow!("bad endpoint in '{s}'"))?,
            ))
        })
        .collect()
}

fn load_matrix(spec: &str, seed: u64) -> Result<Coo> {
    if spec.ends_with(".mtx") {
        return matrix_market::read_matrix_market_file(spec).map_err(|e| anyhow!("{e}"));
    }
    let suite = gen::paper_suite(seed);
    suite
        .into_iter()
        .find(|(n, _)| *n == spec)
        .map(|(_, m)| m)
        .ok_or_else(|| {
            anyhow!("unknown matrix '{spec}' — use a .mtx path or one of: cant, circuit5M, cop20k_A, Ga41As41H72, in-2004, mac_econ_fwd500, mc2depi, scircuit")
        })
}

fn dispatch(args: &[String]) -> Result<()> {
    let (pos, flags) = parse_flags(args);
    let seed = get_usize(&flags, "seed", 42) as u64;
    match pos.first().map(String::as_str) {
        Some("partition") => cmd_partition(&flags, seed),
        Some("cg") => cmd_cg(&flags, seed),
        Some("simulate") => cmd_simulate(&flags, seed),
        Some("bench") => cmd_bench(pos.get(1).map(String::as_str).unwrap_or("all"), seed),
        Some("bench-compare") => cmd_bench_compare(&pos, &flags),
        Some("artifacts") => cmd_artifacts(&flags),
        Some("serve") => cmd_serve(&flags),
        Some("client") => cmd_client(&flags),
        Some("info") => cmd_info(),
        _ => {
            println!(
                "epgraph — edge-centric graph partitioning for GPU caching\n\n\
                 usage:\n  epgraph partition --matrix <name|file.mtx> [--k N] [--method ep|hypergraph|pg-random|pg-greedy|default]\n  \
                 epgraph cg --matrix <name|poisson:side> [--block N] [--iters N] [--wait]\n  \
                 epgraph simulate --app <b+tree|bfs|cfd|gaussian|particlefilter|streamcluster> [--block N]\n  \
                 epgraph bench <fig4|fig6|table2|fig10|fig11|fig12|table3|fig13|fig14|fig15|ablation|scaling|headline|all>\n  \
                 epgraph bench-compare <baseline.json> <current.json> [--tol 0.25]\n  \
                 epgraph artifacts [--outdir DIR] [--configs t0,s1,m1]\n  \
                 epgraph serve [--port 7878] [--threads 0] [--partition-threads 1] [--queue-cap 64] [--cache-mb 64] [--shards 8]\n                [--snapshot cache.snap] [--snapshot-every 64] [--snapshot-keep 3] [--snapshot-interval 0]\n                [--no-degrade] [--chaos seed=7,worker_panic=0.1,...] [--matrix-dir DIR]\n                [--peers 127.0.0.1:7878,127.0.0.1:7879,...]\n  \
                 epgraph client [--addr 127.0.0.1:7878 | --cluster 127.0.0.1:7878,...] [--op optimize|stats|health|shutdown]\n                 [--gen cfd_mesh:24,24,1 | --matrix NAME]\n                 [--base FINGERPRINT --delta-add u:v,u:v,... --delta-remove u:v,...]\n                 [--k N] [--seed S] [--method M] [--mode fm|lp] [--repeat 1] [--concurrency 1] [--verify] [--pipeline N]\n                 [--deadline-ms N] [--max-retries 8] [--retry-budget-ms 30000]\n  \
                 epgraph info"
            );
            Ok(())
        }
    }
}

fn cmd_partition(flags: &HashMap<String, String>, seed: u64) -> Result<()> {
    let spec = flags.get("matrix").ok_or_else(|| anyhow!("--matrix required"))?;
    let a = load_matrix(spec, seed)?;
    let g = a.affinity_graph();
    let k = get_usize(flags, "k", g.m().div_ceil(exp::BLOCK_SIZE).max(1));
    let method = flags
        .get("method")
        .map(|m| Method::from_name(m).ok_or_else(|| anyhow!("unknown method {m}")))
        .transpose()?
        .unwrap_or(Method::Ep);

    println!("matrix {spec}: {}x{}, nnz={}", a.nrows, a.ncols, a.nnz());
    println!("affinity graph: n={} m={} avg_deg={:.2}", g.n, g.m(), g.avg_degree());
    let t0 = std::time::Instant::now();
    let p = method.partition(&g, k, seed);
    let dt = t0.elapsed();
    println!(
        "{} partition: k={k} quality={} balance={:.3} time={:.3}s",
        method.name(),
        quality::vertex_cut_cost(&g, &p),
        quality::balance_factor(&p),
        dt.as_secs_f64()
    );
    Ok(())
}

fn cmd_cg(flags: &HashMap<String, String>, seed: u64) -> Result<()> {
    let spec = flags.get("matrix").map(String::as_str).unwrap_or("poisson:64");
    let a = if let Some(side) = spec.strip_prefix("poisson:") {
        gen::spd_poisson(side.parse()?)
    } else {
        load_matrix(spec, seed)?
    };
    anyhow::ensure!(a.nrows == a.ncols, "cg needs a square matrix");
    let mut engine = Engine::load(&default_artifacts_dir())?;
    println!("pjrt platform: {}", engine.platform());

    let cfg = CgRunConfig {
        block_size: get_usize(flags, "block", 1024),
        max_iters: get_usize(flags, "iters", 400),
        wait_for_optimizer: flags.contains_key("wait"),
        seed,
        ..Default::default()
    };
    let mut rng = Pcg32::new(seed);
    let rhs: Vec<f32> = (0..a.nrows).map(|_| rng.gen_f32() - 0.5).collect();
    let report = run_cg(&mut engine, &a, &rhs, &cfg)?;
    println!(
        "cg: {} iterations, residual {:.3e}, wall {:.3}s",
        report.iterations, report.residual, report.wall_time.as_secs_f64()
    );
    println!(
        "schedule: default quality {} -> optimized {:?} (partition {:.3}s, switched at {:?}, fell back: {})",
        report.quality_default,
        report.quality_optimized,
        report.partition_time.as_secs_f64(),
        report.switched_at,
        report.fell_back
    );
    println!(
        "simulated kernel: original {} cyc/iter, optimized {:?} cyc/iter, speedup {:?}",
        report.sim_original.cycles,
        report.sim_optimized.as_ref().map(|s| s.cycles),
        report.kernel_speedup().map(|s| format!("{s:.2}x"))
    );
    Ok(())
}

fn cmd_simulate(flags: &HashMap<String, String>, seed: u64) -> Result<()> {
    let name = flags.get("app").map(String::as_str).unwrap_or("cfd");
    let suite = epgraph::apps::rodinia_suite(seed);
    let app = suite
        .iter()
        .find(|a| a.name == name)
        .ok_or_else(|| anyhow!("unknown app '{name}'"))?;
    let gpu = GpuConfig::default();
    let block = get_usize(flags, "block", app.block_sizes[app.block_sizes.len() - 1]);
    let case = exp::app_case(&gpu, app, block, seed);
    println!("{} @ block {}", case.name, case.block_size);
    println!(
        "original:  {} cycles, {} read tx",
        case.original.cycles, case.original.read_transactions
    );
    println!(
        "EP:        {} cycles, {} read tx (partition {:.3}s, quality {} -> {})",
        case.optimized.cycles,
        case.optimized.read_transactions,
        case.partition_time.as_secs_f64(),
        case.quality_default,
        case.quality_ep
    );
    Ok(())
}

fn cmd_bench(which: &str, seed: u64) -> Result<()> {
    let gpu = GpuConfig::default();
    match which {
        "fig4" | "fig5" => exp::fig4_degree(seed).print(),
        "fig6" => exp::fig6_table(&exp::fig6_partition(seed)).print(),
        "table2" | "fig10" | "fig11" | "fig12" => {
            println!("== building SPMV suite (8 matrices) ==");
            let cases = exp::table2_cases(&gpu, seed);
            match which {
                "table2" => exp::table2_table(&cases).print(),
                "fig10" => exp::fig10_table(&cases).print(),
                "fig11" => exp::fig11_table(&cases).print(),
                _ => exp::fig12_table(&cases).print(),
            }
        }
        "table3" => exp::table3_table(&gpu, seed).print(),
        "fig13" | "fig14" | "fig15" => {
            println!("== building application suite ==");
            let cases = exp::fig13_cases(&gpu, seed);
            match which {
                "fig13" => exp::fig13_table(&cases).print(),
                "fig14" => exp::fig14_table(&cases).print(),
                _ => exp::fig15_table(&cases).print(),
            }
        }
        "ablation" => exp::ablation_table(seed).print(),
        "scaling" => exp::partition_scaling_table(seed).print(),
        "headline" => println!("{}", exp::redundancy_headline(seed)),
        "all" => {
            println!("### Fig 4/5: degree distributions");
            exp::fig4_degree(seed).print();
            println!("\n### {}", exp::redundancy_headline(seed));
            println!("\n### Fig 6: partition model comparison");
            exp::fig6_table(&exp::fig6_partition(seed)).print();
            println!("\n### Table 2 / Fig 10 / Fig 11 / Fig 12: SPMV");
            let cases = exp::table2_cases(&gpu, seed);
            exp::table2_table(&cases).print();
            println!();
            exp::fig10_table(&cases).print();
            println!();
            exp::fig11_table(&cases).print();
            println!();
            exp::fig12_table(&cases).print();
            println!("\n### Table 3: thread block sizes");
            exp::table3_table(&gpu, seed).print();
            println!("\n### Fig 13/14/15: applications");
            let apps = exp::fig13_cases(&gpu, seed);
            exp::fig13_table(&apps).print();
            println!();
            exp::fig14_table(&apps).print();
            println!();
            exp::fig15_table(&apps).print();
            println!("\n### Ablations");
            exp::ablation_table(seed).print();
            println!("\n### Partition-time scaling");
            exp::partition_scaling_table(seed).print();
        }
        other => return Err(anyhow!("unknown bench target '{other}'")),
    }
    Ok(())
}

/// Emit the AOT artifacts (HLO text + manifest.json) with the rust
/// emitter — the offline replacement for `make artifacts` (which needs
/// Python+JAX; see runtime::aot for when each path is preferred).
fn cmd_artifacts(flags: &HashMap<String, String>) -> Result<()> {
    let outdir = flags
        .get("outdir")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(default_artifacts_dir);
    let names: Vec<String> = flags
        .get("configs")
        .map(|s| s.split(',').map(|c| c.trim().to_string()).filter(|c| !c.is_empty()).collect())
        .unwrap_or_else(|| {
            epgraph::runtime::aot::DEFAULT_CONFIGS.iter().map(|s| s.to_string()).collect()
        });
    let name_refs: Vec<&str> = names.iter().map(String::as_str).collect();
    let n = epgraph::runtime::aot::emit(&outdir, &name_refs)?;
    println!("wrote {n} artifacts ({}) to {outdir:?}", names.join(", "));
    println!("verify with `epgraph info`; tests pick them up via EPGRAPH_ARTIFACTS={outdir:?}");
    Ok(())
}

/// CI bench-regression gate: compare a fresh BENCH_partition.json
/// against the committed baseline; exit non-zero on a >tol regression
/// of any ratio-style headline metric (see benchkit::compare_baselines).
fn cmd_bench_compare(pos: &[String], flags: &HashMap<String, String>) -> Result<()> {
    let usage = "usage: epgraph bench-compare <baseline.json> <current.json> [--tol 0.25]";
    let base_path = pos.get(1).ok_or_else(|| anyhow!("{usage}"))?;
    let cur_path = pos.get(2).ok_or_else(|| anyhow!("{usage}"))?;
    let tol = flags
        .get("tol")
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(0.25);
    if !std::path::Path::new(base_path.as_str()).exists() {
        println!(
            "bench-compare: no committed baseline at {base_path} — bootstrap run, gate skipped \
             (commit the bench artifact as the baseline to arm it)"
        );
        return Ok(());
    }
    let base = std::fs::read_to_string(base_path)
        .map_err(|e| anyhow!("read {base_path}: {e}"))?;
    let cur = std::fs::read_to_string(cur_path).map_err(|e| anyhow!("read {cur_path}: {e}"))?;
    match epgraph::util::benchkit::compare_baselines(&base, &cur, tol) {
        Ok(lines) => {
            println!("bench-compare: {base_path} vs {cur_path} (tol {:.0}%)", tol * 100.0);
            for l in lines {
                println!("  {l}");
            }
            Ok(())
        }
        Err(msg) => Err(anyhow!("{msg}")),
    }
}

/// Start the schedule-serving daemon (service::server).  Blocks until a
/// client sends `{"op":"shutdown"}`; exits 0 on a clean drain.  With
/// `--snapshot PATH` the schedule cache is warm-loaded at startup and
/// snapshotted periodically and at shutdown (rotated generations, see
/// `--snapshot-keep` / `--snapshot-interval`); `--matrix-dir DIR`
/// enables server-side `{"matrix":"name"}` specs (`<DIR>/<name>.mtx`).
/// `--chaos SPEC` (or the EPGRAPH_CHAOS env var) arms deterministic
/// fault injection; `--no-degrade` disables the fallback pipeline.
/// `--peers` joins a sharded fleet: the comma list (which must include
/// this daemon's own `127.0.0.1:<port>`) defines a consistent-hash
/// ring, and requests whose fingerprint another member owns are
/// forwarded there instead of recomputed.
fn cmd_serve(flags: &HashMap<String, String>) -> Result<()> {
    let chaos = flags
        .get("chaos")
        .cloned()
        .or_else(|| std::env::var("EPGRAPH_CHAOS").ok().filter(|s| !s.is_empty()));
    let peers: Vec<String> = flags
        .get("peers")
        .map(|s| s.split(',').map(|a| a.trim().to_string()).filter(|a| !a.is_empty()).collect())
        .unwrap_or_default();
    let opts = epgraph::service::ServeOpts {
        port: get_usize(flags, "port", 7878) as u16,
        threads: get_usize(flags, "threads", 0),
        partition_threads: get_usize(flags, "partition-threads", 1),
        queue_cap: get_usize(flags, "queue-cap", 64),
        cache_bytes: get_usize(flags, "cache-mb", 64) << 20,
        shards: get_usize(flags, "shards", 8),
        snapshot: flags.get("snapshot").map(std::path::PathBuf::from),
        snapshot_every: get_usize(flags, "snapshot-every", 64) as u64,
        snapshot_keep: get_usize(flags, "snapshot-keep", 3).max(1),
        snapshot_interval_secs: get_usize(flags, "snapshot-interval", 0) as u64,
        degrade: !flags.contains_key("no-degrade"),
        chaos,
        matrix_dir: flags.get("matrix-dir").map(std::path::PathBuf::from),
        peers,
    };
    let server = epgraph::service::Server::bind(opts.clone())?;
    println!(
        "epgraph serve: listening on {} (workers={}, queue_cap={}, cache={}MiB/{} shards)",
        server.local_addr(),
        server.workers(),
        opts.queue_cap,
        opts.cache_bytes >> 20,
        opts.shards
    );
    if let Some(warm) = server.warm_report() {
        println!(
            "epgraph serve: warm-start from {:?}: loaded {} entries (skipped: {} corrupt, {} over budget{})",
            opts.snapshot.as_ref().unwrap(),
            warm.loaded,
            warm.skipped_corrupt,
            warm.skipped_budget,
            if warm.version_mismatch {
                ", snapshot version mismatch — whole file skipped"
            } else if warm.oversize_file {
                ", snapshot larger than the loader cap — whole file skipped"
            } else {
                ""
            }
        );
    }
    if let Some(dir) = &opts.matrix_dir {
        println!("epgraph serve: matrix specs resolve from {dir:?}");
    }
    if !opts.peers.is_empty() {
        let ring = epgraph::service::HashRing::new(&opts.peers).map_err(|e| anyhow!("{e}"))?;
        println!(
            "epgraph serve: fleet member 127.0.0.1:{} of {} peers (ring generation {:016x})",
            opts.port,
            ring.len(),
            ring.generation()
        );
    }
    server.run()?;
    println!("epgraph serve: clean shutdown");
    Ok(())
}

/// Drive a running `epgraph serve`: fire optimize requests (optionally
/// concurrent and repeated, with verification against a direct
/// `optimize_graph` run), send delta requests against an already-served
/// schedule (`--base <fingerprint> --delta-add/--delta-remove`, raw
/// JSON responses printed for scripting), or hit the
/// stats/health/shutdown endpoints.
/// `--cluster HOST:PORT,...` hashes the workload client-side with the
/// same ring the fleet uses and talks straight to the owner (skipping
/// the server-side forwarding hop); stats/health/shutdown fan out to
/// every listed node.
fn cmd_client(flags: &HashMap<String, String>) -> Result<()> {
    use epgraph::coordinator::{optimize_graph, OptOptions};
    use epgraph::service::proto;
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Mutex;

    let cluster = flags
        .get("cluster")
        .map(|s| -> Result<epgraph::service::Cluster> {
            anyhow::ensure!(
                !flags.contains_key("addr"),
                "--addr and --cluster are mutually exclusive"
            );
            let addrs: Vec<String> = s
                .split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect();
            epgraph::service::Cluster::new(&addrs)
        })
        .transpose()?;
    let addr = flags.get("addr").cloned().unwrap_or_else(|| "127.0.0.1:7878".to_string());
    let op = flags.get("op").map(String::as_str).unwrap_or("optimize");

    if matches!(op, "stats" | "health" | "shutdown") {
        if let Some(cluster) = &cluster {
            // fan out: these endpoints are per-node, not per-key.  A
            // node that refuses the connection is reported but does not
            // abort the sweep (shutdown of a half-dead fleet must work).
            let mut failures = 0usize;
            for node in cluster.addrs() {
                match epgraph::service::Client::connect(node.as_str())
                    .and_then(|mut c| c.request(&proto::simple_request(op)))
                {
                    Ok(resp) => {
                        println!("{node} {}", resp.dump());
                        if resp.get("ok").and_then(epgraph::util::json::Json::as_bool)
                            != Some(true)
                        {
                            failures += 1;
                        }
                    }
                    Err(e) => {
                        println!("{node} unreachable: {e:#}");
                        failures += 1;
                    }
                }
            }
            anyhow::ensure!(failures == 0, "{failures} fleet node(s) failed '{op}'");
            return Ok(());
        }
        let mut client = epgraph::service::Client::connect(addr.as_str())?;
        let resp = client.request(&proto::simple_request(op))?;
        println!("{}", resp.dump());
        anyhow::ensure!(
            resp.get("ok").and_then(epgraph::util::json::Json::as_bool) == Some(true),
            "server reported failure"
        );
        return Ok(());
    }
    anyhow::ensure!(op == "optimize", "unknown --op '{op}'");

    let mut opts = OptOptions { k: get_usize(flags, "k", 8), ..Default::default() };
    if let Some(s) = flags.get("seed") {
        opts.seed = s.parse().map_err(|_| anyhow!("bad --seed"))?;
    }
    if let Some(m) = flags.get("method") {
        opts.method = epgraph::partition::Method::from_name(m)
            .ok_or_else(|| anyhow!("unknown method {m}"))?;
    }
    if let Some(m) = flags.get("mode") {
        opts.mode = epgraph::partition::Mode::from_name(m)
            .ok_or_else(|| anyhow!("unknown mode {m} (expected fm|lp)"))?;
    }
    let repeat = get_usize(flags, "repeat", 1).max(1);
    let concurrency = get_usize(flags, "concurrency", 1).clamp(1, repeat);
    let verify = flags.contains_key("verify");
    let pipeline = get_usize(flags, "pipeline", 0);
    let deadline_ms =
        flags.get("deadline-ms").map(|v| v.parse::<u64>().map_err(|_| anyhow!("bad --deadline-ms"))).transpose()?;
    let retry_policy = epgraph::service::RetryPolicy::builder()
        .max_retries(get_usize(flags, "max-retries", 8) as u32)
        .budget(std::time::Duration::from_millis(get_usize(flags, "retry-budget-ms", 30_000) as u64))
        .build();

    // --base: a delta request against an already-served schedule.  The
    // raw JSON responses are printed one per line — the CI delta-smoke
    // greps them for the served fingerprint (to chain the next delta on
    // it) and for schedule identity with the equivalent inline request.
    if flags.contains_key("base")
        || flags.contains_key("delta-add")
        || flags.contains_key("delta-remove")
    {
        let base_hex = flags
            .get("base")
            .ok_or_else(|| anyhow!("--delta-add/--delta-remove need --base <fingerprint>"))?;
        let base = epgraph::service::Fingerprint::from_hex(base_hex).ok_or_else(|| {
            anyhow!("--base must be the 32-hex-digit fingerprint of a served schedule")
        })?;
        for bad in ["gen", "matrix", "verify", "pipeline", "cluster"] {
            anyhow::ensure!(
                !flags.contains_key(bad),
                "--base and --{bad} are mutually exclusive — a delta names its graph by base \
                 fingerprint, and fleets route deltas server-side (chains live with the base's \
                 owner, so point --addr at any member)"
            );
        }
        let delta = epgraph::graph::EdgeDelta {
            add_edges: parse_edge_pairs(flags.get("delta-add").map(String::as_str))?,
            remove_edges: parse_edge_pairs(flags.get("delta-remove").map(String::as_str))?,
        };
        anyhow::ensure!(!delta.is_empty(), "--base needs --delta-add and/or --delta-remove");
        let line = proto::delta_request(base, &delta, &opts, deadline_ms).dump();
        let mut client = epgraph::service::Client::connect(addr.as_str())?;
        let mut backoff = epgraph::service::Backoff::new(retry_policy);
        for _ in 0..repeat {
            let resp = client.request_with_retry(&line, &mut backoff)?;
            println!("{}", resp.dump());
            anyhow::ensure!(
                resp.get("ok").and_then(|v| v.as_bool()) == Some(true),
                "delta request failed: {}",
                resp.get("error").and_then(|v| v.as_str()).unwrap_or("unknown error")
            );
        }
        return Ok(());
    }

    let spec = if let Some(name) = flags.get("matrix") {
        anyhow::ensure!(
            !flags.contains_key("gen"),
            "--matrix and --gen are mutually exclusive"
        );
        proto::GraphSpec::Matrix { name: name.clone() }
    } else {
        let spec_str = flags.get("gen").map(String::as_str).unwrap_or("cfd_mesh:24,24,1");
        proto::GraphSpec::parse_cli(spec_str).map_err(|e| anyhow!("--gen: {e}"))?
    };

    // --cluster: hash the workload with the fleet's own ring and talk
    // to the owner directly.  Routing is an optimization, not a
    // correctness requirement — if the owner is down, connect_for
    // probes the remaining nodes and server-side re-home covers it.
    let addr = if let Some(cluster) = &cluster {
        anyhow::ensure!(
            !matches!(spec, proto::GraphSpec::Matrix { .. }),
            "--cluster hashes the workload client-side, but matrix specs resolve on the \
             server — use a --gen workload"
        );
        let g = spec.resolve().map_err(|e| anyhow!("--gen: {e}"))?;
        let fp = epgraph::service::fingerprint(&g, &opts);
        let (probe, routed) = cluster.connect_for(fp)?;
        drop(probe);
        println!(
            "cluster: owner {} for fingerprint {} (routed to {routed})",
            cluster.owner(fp),
            fp.to_hex()
        );
        routed
    } else {
        addr
    };

    if pipeline > 0 {
        anyhow::ensure!(
            !verify,
            "--verify compares one blocking response at a time — drop --pipeline to verify"
        );
        anyhow::ensure!(
            concurrency <= 1,
            "--pipeline multiplexes one connection; it does not combine with --concurrency"
        );
        return run_pipelined(&addr, &spec, &opts, deadline_ms, repeat, pipeline);
    }

    // one request line shared by every connection; the expected schedule
    // (for --verify) comes from the same resolution path the server uses
    let line = proto::optimize_request_with_deadline(&spec, &opts, deadline_ms).dump();
    let expected = if verify {
        anyhow::ensure!(
            !matches!(spec, proto::GraphSpec::Matrix { .. }),
            "--verify resolves the workload client-side, but matrix specs resolve on the \
             server — use a --gen workload to verify"
        );
        let g = spec.resolve().map_err(|e| anyhow!("--gen: {e}"))?;
        Some(optimize_graph(&g, &opts))
    } else {
        None
    };

    let hits = AtomicU64::new(0);
    let joins = AtomicU64::new(0);
    let misses = AtomicU64::new(0);
    let degraded = AtomicU64::new(0);
    let retries = AtomicU64::new(0);
    let latencies: Mutex<Vec<f64>> = Mutex::new(Vec::with_capacity(repeat));
    let t0 = std::time::Instant::now();

    let ranges = epgraph::util::par::chunk_ranges(repeat, concurrency);
    let results: Vec<Result<()>> = std::thread::scope(|s| {
        let handles: Vec<_> = ranges
            .iter()
            .enumerate()
            .map(|(ti, &(lo, hi))| {
                let (line, addr) = (&line, &addr);
                let (hits, joins, misses, degraded, retries) =
                    (&hits, &joins, &misses, &degraded, &retries);
                let (latencies, expected) = (&latencies, &expected);
                s.spawn(move || -> Result<()> {
                    let mut client = epgraph::service::Client::connect(addr.as_str())?;
                    // per-thread jitter seed: reproducible runs, but
                    // concurrent threads never sleep in lockstep
                    let mut backoff = epgraph::service::Backoff::new(
                        epgraph::service::RetryPolicy {
                            seed: retry_policy.seed ^ (ti as u64).wrapping_mul(0x9E3779B9),
                            ..retry_policy
                        },
                    );
                    for _ in lo..hi {
                        let t = std::time::Instant::now();
                        let resp = client.request_with_retry(line, &mut backoff)?;
                        let ok = resp.get("ok").and_then(|v| v.as_bool()) == Some(true);
                        anyhow::ensure!(
                            ok,
                            "request failed{}: {}",
                            if resp.get("retry_after_ms").is_some() {
                                " (retries exhausted)"
                            } else {
                                ""
                            },
                            resp.get("error")
                                .and_then(|v| v.as_str())
                                .unwrap_or("unknown error")
                        );
                        latencies.lock().unwrap().push(t.elapsed().as_secs_f64() * 1e3);
                        let served_degraded =
                            resp.get("cached").and_then(|v| v.as_str()) == Some("degraded");
                        match resp.get("cached").and_then(|v| v.as_str()) {
                            Some("hit") => hits.fetch_add(1, Ordering::Relaxed),
                            Some("joined") => joins.fetch_add(1, Ordering::Relaxed),
                            Some("degraded") => degraded.fetch_add(1, Ordering::Relaxed),
                            _ => misses.fetch_add(1, Ordering::Relaxed),
                        };
                        // degraded schedules are deliberately NOT the full
                        // pipeline's product — --verify checks full runs only
                        if let Some(exp) = expected.as_ref().filter(|_| !served_degraded) {
                            let assign = resp
                                .get("assign")
                                .and_then(|v| v.as_arr())
                                .ok_or_else(|| anyhow!("response missing assign"))?;
                            let same_assign = assign.len() == exp.partition.assign.len()
                                && assign
                                    .iter()
                                    .zip(&exp.partition.assign)
                                    .all(|(a, &b)| a.as_u64() == Some(b as u64));
                            let layout = resp
                                .get("layout")
                                .and_then(|v| v.as_arr())
                                .ok_or_else(|| anyhow!("response missing layout"))?;
                            let same_layout = layout.len() == exp.layout.new_of_old.len()
                                && layout
                                    .iter()
                                    .zip(&exp.layout.new_of_old)
                                    .all(|(a, &b)| a.as_u64() == Some(b as u64));
                            anyhow::ensure!(
                                same_assign && same_layout,
                                "served schedule differs from direct optimize_graph"
                            );
                        }
                    }
                    retries.fetch_add(u64::from(backoff.attempts()), Ordering::Relaxed);
                    Ok(())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| Err(anyhow!("client thread panicked"))))
            .collect()
    });
    for r in results {
        r?;
    }

    let wall = t0.elapsed();
    let mut lat = latencies.into_inner().unwrap();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat[((p * lat.len() as f64) as usize).min(lat.len() - 1)];
    println!(
        "client: {} ok (hit {}, joined {}, miss {}, degraded {}), backpressure retries {}, wall {:.3}s",
        lat.len(),
        hits.load(Ordering::Relaxed),
        joins.load(Ordering::Relaxed),
        misses.load(Ordering::Relaxed),
        degraded.load(Ordering::Relaxed),
        retries.load(Ordering::Relaxed),
        wall.as_secs_f64()
    );
    println!(
        "latency ms: p50 {:.3} p95 {:.3} max {:.3} (over {} requests, {} connections)",
        pct(0.50),
        pct(0.95),
        lat.last().copied().unwrap_or(0.0),
        lat.len(),
        ranges.len()
    );
    if verify {
        println!(
            "verify: every full response bit-identical to direct optimize_graph{}",
            if degraded.load(Ordering::Relaxed) > 0 {
                " (degraded responses excluded by design)"
            } else {
                ""
            }
        );
    }
    Ok(())
}

/// The `--pipeline N` client path: one connection, a sliding window of
/// N id-stamped requests in flight, responses consumed in whatever
/// order the server completes them (`PipelinedClient::recv` refuses
/// responses that do not pair with an outstanding ticket, so finishing
/// at all proves every response was id-matched).
fn run_pipelined(
    addr: &str,
    spec: &epgraph::service::proto::GraphSpec,
    opts: &epgraph::coordinator::OptOptions,
    deadline_ms: Option<u64>,
    repeat: usize,
    depth: usize,
) -> Result<()> {
    use epgraph::service::proto;

    let req = proto::optimize_request_with_deadline(spec, opts, deadline_ms);
    let mut client = epgraph::service::PipelinedClient::connect(addr)?;
    let (mut hits, mut joins, mut misses, mut degraded) = (0u64, 0u64, 0u64, 0u64);
    let mut sent = 0usize;
    let mut done = 0usize;
    let t0 = std::time::Instant::now();
    while done < repeat {
        while sent < repeat && client.in_flight() < depth {
            client.submit(&req)?;
            sent += 1;
        }
        let (_ticket, resp) = client.recv()?;
        anyhow::ensure!(
            resp.get("ok").and_then(|v| v.as_bool()) == Some(true),
            "request failed: {}",
            resp.get("error").and_then(|v| v.as_str()).unwrap_or("unknown error")
        );
        match resp.get("cached").and_then(|v| v.as_str()) {
            Some("hit") => hits += 1,
            Some("joined") => joins += 1,
            Some("degraded") => degraded += 1,
            _ => misses += 1,
        }
        done += 1;
    }
    let wall = t0.elapsed();
    println!(
        "client: {done} ok (hit {hits}, joined {joins}, miss {misses}, degraded {degraded}), \
         pipeline depth {depth}, all responses id-matched, wall {:.3}s ({:.0} req/s)",
        wall.as_secs_f64(),
        done as f64 / wall.as_secs_f64().max(1e-9)
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!(
        "epgraph {} — reproduction of Li et al. 2016 (EP model for GPU caching)",
        env!("CARGO_PKG_VERSION")
    );
    let dir = default_artifacts_dir();
    match epgraph::runtime::Manifest::load(&dir) {
        Ok(m) => {
            println!("artifacts at {:?}: {} entries", m.dir, m.artifacts.len());
            for a in &m.artifacts {
                println!(
                    "  {}_{}: n_in={} n_out={} k={} e={} c={} ({})",
                    a.entry, a.config, a.n_in, a.n_out, a.k, a.e, a.c, a.file
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    match Engine::load(&dir) {
        Ok(engine) => println!("pjrt: {} OK", engine.platform()),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    Ok(())
}
