//! Sparse-matrix substrate: COO/CSR structures, MatrixMarket IO,
//! synthetic counterparts of the paper's evaluation matrices, the cpack
//! data-layout transform (§4.1), and the BlockedSpmv packing consumed by
//! the AOT kernel.

pub mod blocked;
pub mod coo;
pub mod cpack;
pub mod gen;
pub mod matrix_market;

pub use blocked::{pack_blocked, BlockedShape, BlockedSpmv, PackError};
pub use coo::{Coo, Csr};
pub use cpack::{cpack_spmv, cpack_square, Perm};
