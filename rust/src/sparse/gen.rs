//! Synthetic counterparts of the paper's evaluation matrices.
//!
//! The paper's Table 2 / Fig 6 matrices come from the UF collection and
//! Matrix Market; we cannot ship them, so each generator reproduces the
//! *structural family* (degree distribution + locality pattern, Fig 4/5)
//! at laptop scale.  Scale factor 1.0 targets the `m2`/`l1` artifact
//! configs (dims ≤ 131072, nnz ≤ 262144).  All are seeded/deterministic.

use crate::util::rng::Pcg32;

use super::coo::Coo;

/// cant — FEM cantilever: banded block structure, degrees 20–40.
pub fn cant_s(n: usize, seed: u64) -> Coo {
    let mut rng = Pcg32::new(seed);
    let mut a = Coo::new(n, n);
    let band = 14;
    for i in 0..n {
        a.push(i, i, 4.0 + rng.gen_f32());
        for d in 1..=band {
            if i + d < n && rng.gen_f64() < 0.85 {
                let v = rng.gen_f32() - 0.5;
                a.push(i, i + d, v);
                a.push(i + d, i, v);
            }
        }
    }
    a
}

/// circuit5M — huge circuit: mostly sparse random rows + a few very
/// dense "power rail" rows/cols.
pub fn circuit_s(n: usize, seed: u64) -> Coo {
    let mut rng = Pcg32::new(seed);
    let mut a = Coo::new(n, n);
    for i in 0..n {
        a.push(i, i, 2.0 + rng.gen_f32());
        let deg = 1 + rng.gen_pareto(1.6, 64);
        for _ in 0..deg.min(8) {
            let j = rng.gen_range(n);
            a.push(i, j, rng.gen_f32() - 0.5);
        }
    }
    // dense rails: a handful of rows touching ~1% of columns
    for _ in 0..4 {
        let i = rng.gen_range(n);
        for _ in 0..n / 100 {
            a.push(i, rng.gen_range(n), rng.gen_f32());
        }
    }
    a
}

/// cop20k_A — FEM accelerator cavity: irregular mesh, ~11 nnz/row.
pub fn cop20k_s(n: usize, seed: u64) -> Coo {
    let mut rng = Pcg32::new(seed);
    let mut a = Coo::new(n, n);
    // tetrahedral-mesh flavour: local band + a few medium-range links
    for i in 0..n {
        a.push(i, i, 6.0);
        for _ in 0..5 {
            let off = 1 + rng.gen_range(24);
            if i + off < n {
                let v = rng.gen_f32() - 0.5;
                a.push(i, i + off, v);
                a.push(i + off, i, v);
            }
        }
    }
    a
}

/// Ga41As41H72 — quantum chemistry: dense clustered blocks + long-range
/// fill, ~35 nnz/row (low reuse relative to working set, like the paper).
pub fn ga41as41h72_s(n: usize, seed: u64) -> Coo {
    let mut rng = Pcg32::new(seed);
    let mut a = Coo::new(n, n);
    let cluster = 16;
    for i in 0..n {
        a.push(i, i, 8.0);
        let base = (i / cluster) * cluster;
        // dense intra-cluster coupling
        for j in base..(base + cluster).min(n) {
            if j != i && rng.gen_f64() < 0.5 {
                a.push(i, j, rng.gen_f32() - 0.5);
            }
        }
        // scattered long-range entries
        for _ in 0..6 {
            a.push(i, rng.gen_range(n), rng.gen_f32() * 0.1);
        }
    }
    a
}

/// in-2004 — web graph: power-law in/out degrees (hub pages).
pub fn in2004_s(n: usize, seed: u64) -> Coo {
    let g = crate::graph::gen::power_law(n, 3, seed);
    let mut rng = Pcg32::new(seed ^ 0xFEED);
    let mut a = Coo::new(n, n);
    for &(u, v) in &g.edges {
        a.push(u as usize, v as usize, rng.gen_f32());
        // web links are directed; mirror ~30% to mimic reciprocal links
        if rng.gen_f64() < 0.3 {
            a.push(v as usize, u as usize, rng.gen_f32());
        }
    }
    a
}

/// mac_econ_fwd500 — economic model: narrow irregular band, ~6 nnz/row.
pub fn mac_econ_s(n: usize, seed: u64) -> Coo {
    let mut rng = Pcg32::new(seed);
    let mut a = Coo::new(n, n);
    for i in 0..n {
        a.push(i, i, 3.0);
        for _ in 0..5 {
            // mixture: mostly near-diagonal, occasionally far
            let j = if rng.gen_f64() < 0.8 {
                let off = rng.gen_range(200) + 1;
                if rng.gen_f64() < 0.5 { i.saturating_sub(off) } else { (i + off).min(n - 1) }
            } else {
                rng.gen_range(n)
            };
            if j != i {
                a.push(i, j, rng.gen_f32() - 0.5);
            }
        }
    }
    a
}

/// mc2depi — 2D epidemic Markov chain: 4-point grid stencil, degree
/// almost uniformly 4 (the paper: 99.4% of vertices).
pub fn mc2depi_s(side: usize, seed: u64) -> Coo {
    let mut rng = Pcg32::new(seed);
    let n = side * side;
    let mut a = Coo::new(n, n);
    let at = |r: usize, c: usize| r * side + c;
    for r in 0..side {
        for c in 0..side {
            let i = at(r, c);
            // transitions to 4 neighbours (wrapping at the border keeps
            // the degree exactly 4, matching mc2depi's near-uniformity)
            let nbrs = [
                at((r + 1) % side, c),
                at((r + side - 1) % side, c),
                at(r, (c + 1) % side),
                at(r, (c + side - 1) % side),
            ];
            for j in nbrs {
                a.push(i, j, 0.2 + 0.1 * rng.gen_f32());
            }
        }
    }
    a
}

/// scircuit — circuit simulation: power-law-ish with degree-2 chains.
/// Node labels are scrambled: circuit netlist node numbering carries no
/// layout locality, so (as in the paper, where default quality is ~35x
/// worse than EP) the default contiguous schedule must not get mesh-like
/// locality for free.
pub fn scircuit_s(n: usize, seed: u64) -> Coo {
    let mut rng = Pcg32::new(seed);
    let mut relabel: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut relabel);
    let mut a = Coo::new(n, n);
    for i in 0..n {
        a.push(relabel[i], relabel[i], 2.0);
        // serial chain (wires)
        if i + 1 < n {
            let v = rng.gen_f32() - 0.5;
            a.push(relabel[i], relabel[i + 1], v);
            a.push(relabel[i + 1], relabel[i], v);
        }
        // occasional fan-out to a power-law hub
        if rng.gen_f64() < 0.35 {
            let hub = rng.gen_pareto(1.4, n.max(2) - 1) - 1;
            if hub != i {
                a.push(relabel[i], relabel[hub], rng.gen_f32() * 0.3);
            }
        }
    }
    // ship row-major like a real .mtx: under the scrambled labels this
    // destroys the chain adjacency in task order, so the default
    // contiguous schedule gets no free locality (as with real scircuit)
    a.sort_row_major();
    a
}

/// SPD 2D Poisson (5-point Laplacian) — the CG end-to-end system.
pub fn spd_poisson(side: usize) -> Coo {
    let n = side * side;
    let mut a = Coo::new(n, n);
    let at = |r: usize, c: usize| r * side + c;
    for r in 0..side {
        for c in 0..side {
            let i = at(r, c);
            a.push(i, i, 4.0);
            if r > 0 {
                a.push(i, at(r - 1, c), -1.0);
            }
            if r + 1 < side {
                a.push(i, at(r + 1, c), -1.0);
            }
            if c > 0 {
                a.push(i, at(r, c - 1), -1.0);
            }
            if c + 1 < side {
                a.push(i, at(r, c + 1), -1.0);
            }
        }
    }
    a
}

/// The paper's Table-2 suite at laptop scale, in the paper's order.
pub fn paper_suite(seed: u64) -> Vec<(&'static str, Coo)> {
    vec![
        ("cant", cant_s(4096, seed)),
        ("circuit5M", circuit_s(24576, seed + 1)),
        ("cop20k_A", cop20k_s(16384, seed + 2)),
        ("Ga41As41H72", ga41as41h72_s(8192, seed + 3)),
        ("in-2004", in2004_s(16384, seed + 4)),
        ("mac_econ_fwd500", mac_econ_s(16384, seed + 5)),
        ("mc2depi", mc2depi_s(128, seed + 6)),
        ("scircuit", scircuit_s(16384, seed + 7)),
    ]
}

/// The Fig-6 partition-comparison subset (5 graphs, paper's order).
pub fn fig6_suite(seed: u64) -> Vec<(&'static str, Coo)> {
    vec![
        ("cant", cant_s(4096, seed)),
        ("circuit5M", circuit_s(24576, seed + 1)),
        ("in-2004", in2004_s(16384, seed + 4)),
        ("mc2depi", mc2depi_s(128, seed + 6)),
        ("scircuit", scircuit_s(16384, seed + 7)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::stats;

    #[test]
    fn suite_fits_artifact_limits() {
        for (name, m) in paper_suite(42) {
            assert!(m.nrows.max(m.ncols) <= 131072, "{name} dims");
            assert!(m.nnz() <= 262144, "{name} nnz {}", m.nnz());
            assert!(m.nnz() > 10_000, "{name} too small: {}", m.nnz());
        }
    }

    #[test]
    fn mc2depi_degree_is_four() {
        let m = mc2depi_s(64, 1);
        let g = m.affinity_graph();
        // x-side vertices: each column appears exactly 4 times
        let h = g.degree_histogram();
        let frac4 = h.get(4).copied().unwrap_or(0) as f64 / g.n as f64;
        assert!(frac4 > 0.95, "frac4 {frac4}");
    }

    #[test]
    fn in2004_is_power_law() {
        let m = in2004_s(8192, 3);
        let g = m.affinity_graph();
        let slope = stats::log_log_slope(&g).expect("power law has many degrees");
        assert!(slope < -0.7, "slope {slope}");
    }

    #[test]
    fn cant_band_structure() {
        let m = cant_s(2048, 5);
        // banded: |i - j| ≤ band for all entries
        for t in 0..m.nnz() {
            let d = (m.rows[t] as i64 - m.cols[t] as i64).abs();
            assert!(d <= 14, "bandwidth violated: {d}");
        }
        let g = m.affinity_graph();
        assert!(g.avg_degree() > 10.0, "cant should be dense-ish");
    }

    #[test]
    fn spd_poisson_is_symmetric_diag_dominant() {
        let m = spd_poisson(16);
        let t = m.transpose();
        // symmetric: spmv equal on a probe vector
        let mut rng = Pcg32::new(7);
        let x: Vec<f32> = (0..m.ncols).map(|_| rng.gen_f32()).collect();
        let ax = m.spmv(&x);
        let atx = t.spmv(&x);
        for (a, b) in ax.iter().zip(&atx) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn generators_deterministic() {
        let a = scircuit_s(1000, 9);
        let b = scircuit_s(1000, 9);
        assert_eq!(a.vals, b.vals);
        assert_eq!(a.cols, b.cols);
    }
}
