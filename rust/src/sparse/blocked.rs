//! BlockedSpmv: the runtime data format consumed by the AOT kernel.
//!
//! Mirrors python/compile/blocked.py — given a COO matrix and an edge
//! partition, pack each block's tasks into padded gather lists:
//!
//!   x_gather[k, c]    global x-indices the block stages ("smem fill")
//!   cols_local[k, e]  per-task index into the staged copy
//!   vals[k, e]        per-task matrix value (0 padding)
//!   rows_global[k, e] output row per task (padding → n_out dump slot)
//!
//! The arrays are stored flat row-major, ready to hand to PJRT literals.

use crate::partition::EdgePartition;

use super::coo::Coo;

/// Shape limits of one AOT artifact config (mirrors configs.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BlockedShape {
    pub n_in: usize,
    pub n_out: usize,
    pub k: usize,
    pub e: usize,
    pub c: usize,
}

#[derive(Clone, Debug)]
pub struct BlockedSpmv {
    pub shape: BlockedShape,
    pub x_gather: Vec<i32>,
    pub cols_local: Vec<i32>,
    pub vals: Vec<f32>,
    pub rows_global: Vec<i32>,
    /// real (unpadded) dims, for unpacking results
    pub nrows: usize,
    pub ncols: usize,
    /// per-block count of staged columns (the block's smem footprint)
    pub staged_len: Vec<usize>,
    /// per-block task counts
    pub task_len: Vec<usize>,
}

#[derive(Debug, PartialEq, Eq)]
pub enum PackError {
    /// a block holds more tasks than `e`
    BlockTooLarge { block: usize, tasks: usize, e: usize },
    /// a block stages more unique columns than `c`
    StageTooLarge { block: usize, staged: usize, c: usize },
    /// matrix dims exceed the config
    DimsTooLarge,
    /// partition has more blocks than the config
    TooManyBlocks { k_part: usize, k_cfg: usize },
}

impl std::fmt::Display for PackError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PackError::BlockTooLarge { block, tasks, e } => {
                write!(f, "block {block} has {tasks} tasks > e={e}")
            }
            PackError::StageTooLarge { block, staged, c } => {
                write!(f, "block {block} stages {staged} cols > c={c}")
            }
            PackError::DimsTooLarge => write!(f, "matrix dims exceed config"),
            PackError::TooManyBlocks { k_part, k_cfg } => {
                write!(f, "partition k={k_part} > config k={k_cfg}")
            }
        }
    }
}

impl std::error::Error for PackError {}

/// Pack a COO matrix under an edge partition into the blocked format.
/// Partitions with fewer blocks than the config leave trailing blocks
/// empty (all-padding); that is harmless at execution time.
pub fn pack_blocked(
    a: &Coo,
    p: &EdgePartition,
    shape: BlockedShape,
) -> Result<BlockedSpmv, PackError> {
    if a.ncols > shape.n_in || a.nrows > shape.n_out {
        return Err(PackError::DimsTooLarge);
    }
    if p.k > shape.k {
        return Err(PackError::TooManyBlocks { k_part: p.k, k_cfg: shape.k });
    }
    let (k, e, c) = (shape.k, shape.e, shape.c);
    let mut x_gather = vec![0i32; k * c];
    let mut cols_local = vec![0i32; k * e];
    let mut vals = vec![0f32; k * e];
    let mut rows_global = vec![shape.n_out as i32; k * e];

    // bucket tasks per block, preserving task order within blocks
    let mut counts = vec![0usize; k];
    for &b in &p.assign {
        counts[b as usize] += 1;
    }
    for (b, &cnt) in counts.iter().enumerate() {
        if cnt > e {
            return Err(PackError::BlockTooLarge { block: b, tasks: cnt, e });
        }
    }
    let mut starts = vec![0usize; k + 1];
    for b in 0..k {
        starts[b + 1] = starts[b] + counts[b];
    }
    let mut order = vec![0usize; a.nnz()];
    let mut cursor = starts[..k].to_vec();
    for t in 0..a.nnz() {
        let b = p.assign[t] as usize;
        order[cursor[b]] = t;
        cursor[b] += 1;
    }

    // per block: local dictionary of staged columns (epoch-stamped)
    let mut local_of_col = vec![u32::MAX; a.ncols];
    let mut staged_cols: Vec<u32> = Vec::with_capacity(c);
    let mut staged_len = vec![0usize; k];
    for b in 0..k {
        staged_cols.clear();
        for (slot, &t) in order[starts[b]..starts[b + 1]].iter().enumerate() {
            let col = a.cols[t];
            let local = if local_of_col[col as usize] == u32::MAX {
                let l = staged_cols.len() as u32;
                if l as usize >= c {
                    return Err(PackError::StageTooLarge { block: b, staged: l as usize + 1, c });
                }
                local_of_col[col as usize] = l;
                staged_cols.push(col);
                l
            } else {
                local_of_col[col as usize]
            };
            cols_local[b * e + slot] = local as i32;
            vals[b * e + slot] = a.vals[t];
            rows_global[b * e + slot] = a.rows[t] as i32;
        }
        for (l, &col) in staged_cols.iter().enumerate() {
            x_gather[b * c + l] = col as i32;
            local_of_col[col as usize] = u32::MAX; // reset for next block
        }
        staged_len[b] = staged_cols.len();
    }

    Ok(BlockedSpmv {
        shape,
        x_gather,
        cols_local,
        vals,
        rows_global,
        nrows: a.nrows,
        ncols: a.ncols,
        staged_len,
        task_len: counts,
    })
}

impl BlockedSpmv {
    /// Pure-rust reference execution (the oracle the PJRT path is tested
    /// against, and the no-artifact fallback).
    pub fn execute_ref(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.ncols);
        let s = self.shape;
        let mut y = vec![0f32; s.n_out + 1];
        let mut staged = vec![0f32; s.c];
        for b in 0..s.k {
            for l in 0..s.c {
                let gi = self.x_gather[b * s.c + l] as usize;
                staged[l] = if gi < x.len() { x[gi] } else { 0.0 };
            }
            for t in 0..s.e {
                let v = self.vals[b * s.e + t];
                let xl = staged[self.cols_local[b * s.e + t] as usize];
                y[self.rows_global[b * s.e + t] as usize] += v * xl;
            }
        }
        y.truncate(self.nrows);
        y
    }

    /// Padded x input for the PJRT executable (length n_in).
    pub fn pad_x(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.ncols);
        let mut p = vec![0f32; self.shape.n_in];
        p[..x.len()].copy_from_slice(x);
        p
    }

    /// Padding waste: fraction of (k·e) task slots that are padding —
    /// the L1 kernel's wasted VPU lanes, tracked by the perf pass.
    pub fn padding_waste(&self) -> f64 {
        let total = (self.shape.k * self.shape.e) as f64;
        let used: usize = self.task_len.iter().sum();
        1.0 - used as f64 / total
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::default_sched::default_partition;
    use crate::partition::Method;
    use crate::sparse::gen;
    use crate::util::rng::Pcg32;

    fn shape(n: usize, k: usize, e: usize, c: usize) -> BlockedShape {
        BlockedShape { n_in: n, n_out: n, k, e, c }
    }

    #[test]
    fn pack_and_execute_matches_coo() {
        let a = gen::spd_poisson(16);
        let p = default_partition(a.nnz(), 8);
        let b = pack_blocked(&a, &p, shape(1024, 8, 256, 256)).unwrap();
        let mut rng = Pcg32::new(1);
        let x: Vec<f32> = (0..a.ncols).map(|_| rng.gen_f32()).collect();
        let y1 = a.spmv(&x);
        let y2 = b.execute_ref(&x);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-4, "{u} vs {v}");
        }
    }

    #[test]
    fn ep_partition_packs_and_matches() {
        let a = gen::scircuit_s(900, 4);
        let g = a.affinity_graph();
        let p = Method::Ep.partition(&g, 8, 2);
        let b = pack_blocked(&a, &p, shape(1024, 8, 512, 512)).unwrap();
        let mut rng = Pcg32::new(2);
        let x: Vec<f32> = (0..a.ncols).map(|_| rng.gen_f32()).collect();
        let y1 = a.spmv(&x);
        let y2 = b.execute_ref(&x);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-3, "{u} vs {v}");
        }
    }

    #[test]
    fn overflow_errors() {
        let a = gen::spd_poisson(16); // 256 rows, ~1216 nnz
        let p = default_partition(a.nnz(), 2);
        match pack_blocked(&a, &p, shape(1024, 2, 64, 512)) {
            Err(PackError::BlockTooLarge { .. }) => {}
            other => panic!("expected BlockTooLarge, got {other:?}"),
        }
        match pack_blocked(&a, &p, shape(128, 2, 1024, 1024)) {
            Err(PackError::DimsTooLarge) => {}
            other => panic!("expected DimsTooLarge, got {other:?}"),
        }
        let p8 = default_partition(a.nnz(), 8);
        match pack_blocked(&a, &p8, shape(1024, 2, 1024, 1024)) {
            Err(PackError::TooManyBlocks { .. }) => {}
            other => panic!("expected TooManyBlocks, got {other:?}"),
        }
    }

    #[test]
    fn stage_limit_enforced() {
        // a block with e tasks all hitting distinct columns needs c >= e
        let mut a = Coo::new(4, 64);
        for j in 0..64 {
            a.push(j % 4, j, 1.0);
        }
        let p = default_partition(64, 1);
        match pack_blocked(&a, &p, shape(64, 1, 64, 16)) {
            Err(PackError::StageTooLarge { .. }) => {}
            other => panic!("{other:?}"),
        }
        assert!(pack_blocked(&a, &p, shape(64, 1, 64, 64)).is_ok());
    }

    #[test]
    fn fewer_blocks_than_config_is_fine() {
        let a = gen::spd_poisson(8);
        let p = default_partition(a.nnz(), 2);
        let b = pack_blocked(&a, &p, shape(256, 8, 256, 256)).unwrap();
        let x = vec![1f32; a.ncols];
        let y1 = a.spmv(&x);
        let y2 = b.execute_ref(&x);
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-4);
        }
    }
}
