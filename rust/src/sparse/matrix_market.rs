//! MatrixMarket (.mtx) reader/writer — the paper's inputs come from the
//! UF Sparse Matrix Collection and Matrix Market; this lets users feed
//! real downloads to the CLI while the benches default to synthetic
//! counterparts.
//!
//! Supports `matrix coordinate real|integer|pattern general|symmetric`.

use std::io::{BufRead, BufReader, Read, Write};

use super::coo::Coo;

#[derive(Debug)]
pub enum MmError {
    Io(std::io::Error),
    Parse(String),
}

impl std::fmt::Display for MmError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MmError::Io(e) => write!(f, "io: {e}"),
            MmError::Parse(s) => write!(f, "parse: {s}"),
        }
    }
}

impl std::error::Error for MmError {}

impl From<std::io::Error> for MmError {
    fn from(e: std::io::Error) -> Self {
        MmError::Io(e)
    }
}

pub fn read_matrix_market<R: Read>(r: R) -> Result<Coo, MmError> {
    read_matrix_market_checked(r, |_, _, _| Ok(()))
}

/// Same, with a size hook: `check(nrows, ncols, nnz)` runs right after
/// the size line and before any entry is read, so a caller with a size
/// bound (the serving layer's matrix specs) rejects oversize inputs in
/// O(header) instead of after parsing a multi-GB body.
pub fn read_matrix_market_checked<R: Read>(
    r: R,
    check: impl FnOnce(usize, usize, usize) -> Result<(), String>,
) -> Result<Coo, MmError> {
    let mut lines = BufReader::new(r).lines();
    let header = lines
        .next()
        .ok_or_else(|| MmError::Parse("empty file".into()))??;
    let h: Vec<&str> = header.split_whitespace().collect();
    if h.len() < 4 || !h[0].starts_with("%%MatrixMarket") {
        return Err(MmError::Parse("missing %%MatrixMarket header".into()));
    }
    if h[1] != "matrix" || h[2] != "coordinate" {
        return Err(MmError::Parse(format!("unsupported kind: {} {}", h[1], h[2])));
    }
    let field = h[3]; // real | integer | pattern
    let symmetric = h.get(4).is_some_and(|&s| s == "symmetric");
    if !matches!(field, "real" | "integer" | "pattern") {
        return Err(MmError::Parse(format!("unsupported field: {field}")));
    }

    // skip comments, read size line
    let mut size_line = None;
    for line in lines.by_ref() {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        size_line = Some(t.to_string());
        break;
    }
    let size_line = size_line.ok_or_else(|| MmError::Parse("missing size line".into()))?;
    let dims: Vec<usize> = size_line
        .split_whitespace()
        .map(|s| s.parse::<usize>().map_err(|e| MmError::Parse(format!("size: {e}"))))
        .collect::<Result<_, _>>()?;
    if dims.len() != 3 {
        return Err(MmError::Parse("size line needs 3 numbers".into()));
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);
    check(nrows, ncols, nnz).map_err(MmError::Parse)?;

    let mut coo = Coo::new(nrows, ncols);
    let mut read = 0usize;
    for line in lines {
        let line = line?;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let parts: Vec<&str> = t.split_whitespace().collect();
        if parts.len() < 2 {
            return Err(MmError::Parse(format!("bad entry: {t}")));
        }
        let i: usize = parts[0].parse().map_err(|e| MmError::Parse(format!("{e}")))?;
        let j: usize = parts[1].parse().map_err(|e| MmError::Parse(format!("{e}")))?;
        if i < 1 || j < 1 || i > nrows || j > ncols {
            return Err(MmError::Parse(format!("index out of range: {i} {j}")));
        }
        let v: f32 = if field == "pattern" {
            1.0
        } else {
            parts
                .get(2)
                .ok_or_else(|| MmError::Parse("missing value".into()))?
                .parse()
                .map_err(|e| MmError::Parse(format!("{e}")))?
        };
        coo.push(i - 1, j - 1, v);
        if symmetric && i != j {
            coo.push(j - 1, i - 1, v);
        }
        read += 1;
    }
    if read != nnz {
        return Err(MmError::Parse(format!("expected {nnz} entries, got {read}")));
    }
    Ok(coo)
}

pub fn read_matrix_market_file(path: &str) -> Result<Coo, MmError> {
    read_matrix_market(std::fs::File::open(path)?)
}

/// Resolve `<dir>/<name>.mtx` — the server-side loader behind
/// `{"matrix":"cant"}` specs (`service::proto`).  The name charset is
/// restricted to `[A-Za-z0-9._-]` minus `..`, so a request can never
/// traverse out of the configured matrix directory.  `check` sees the
/// declared `(nrows, ncols, nnz)` before the body is read (see
/// [`read_matrix_market_checked`]).
pub fn read_named(
    dir: &std::path::Path,
    name: &str,
    check: impl FnOnce(usize, usize, usize) -> Result<(), String>,
) -> Result<Coo, MmError> {
    let safe = !name.is_empty()
        && !name.contains("..")
        && name
            .chars()
            .all(|c| c.is_ascii_alphanumeric() || matches!(c, '-' | '_' | '.'));
    if !safe {
        return Err(MmError::Parse(format!(
            "invalid matrix name '{name}' (allowed: letters, digits, '-', '_', '.')"
        )));
    }
    read_matrix_market_checked(std::fs::File::open(dir.join(format!("{name}.mtx")))?, check)
}

pub fn write_matrix_market<W: Write>(w: &mut W, coo: &Coo) -> std::io::Result<()> {
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "{} {} {}", coo.nrows, coo.ncols, coo.nnz())?;
    for t in 0..coo.nnz() {
        writeln!(w, "{} {} {}", coo.rows[t] + 1, coo.cols[t] + 1, coo.vals[t])?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_general_real() {
        let text = "%%MatrixMarket matrix coordinate real general\n% comment\n2 3 3\n1 1 1.5\n2 2 -2\n1 3 4e2\n";
        let m = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!((m.nrows, m.ncols, m.nnz()), (2, 3, 3));
        assert_eq!(m.spmv(&[1.0, 1.0, 1.0]), vec![401.5, -2.0]);
    }

    #[test]
    fn parses_symmetric_pattern() {
        let text = "%%MatrixMarket matrix coordinate pattern symmetric\n3 3 2\n2 1\n3 3\n";
        let m = read_matrix_market(text.as_bytes()).unwrap();
        assert_eq!(m.nnz(), 3); // off-diagonal mirrored, diagonal not
    }

    #[test]
    fn roundtrip() {
        let mut a = Coo::new(3, 2);
        a.push(0, 1, 2.5);
        a.push(2, 0, -1.0);
        let mut buf = Vec::new();
        write_matrix_market(&mut buf, &a).unwrap();
        let b = read_matrix_market(buf.as_slice()).unwrap();
        assert_eq!(a.rows, b.rows);
        assert_eq!(a.cols, b.cols);
        assert_eq!(a.vals, b.vals);
    }

    #[test]
    fn read_named_resolves_and_rejects_traversal() {
        let dir = std::env::temp_dir().join(format!("epgraph-mm-named-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("ok.mtx"),
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 3.0\n",
        )
        .unwrap();
        let ok = |_, _, _| Ok(());
        let m = read_named(&dir, "ok", ok).unwrap();
        assert_eq!((m.nrows, m.ncols, m.nnz()), (2, 2, 1));
        assert!(matches!(read_named(&dir, "missing", ok), Err(MmError::Io(_))));
        for bad in ["", "..", "../ok", "a/b", "a\\b", "ok.mtx/../../etc/passwd"] {
            assert!(
                matches!(read_named(&dir, bad, ok), Err(MmError::Parse(_))),
                "name '{bad}' must be rejected"
            );
        }
        // the size hook fires before the body is read
        let err = read_named(&dir, "ok", |r, c, z| Err(format!("too big: {r}x{c}/{z}")))
            .unwrap_err();
        assert!(err.to_string().contains("too big: 2x2/1"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_headers_and_counts() {
        assert!(read_matrix_market("garbage\n".as_bytes()).is_err());
        assert!(read_matrix_market(
            "%%MatrixMarket matrix array real general\n2 2\n".as_bytes()
        )
        .is_err());
        assert!(read_matrix_market(
            "%%MatrixMarket matrix coordinate real general\n2 2 5\n1 1 1\n".as_bytes()
        )
        .is_err());
        assert!(read_matrix_market(
            "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1\n".as_bytes()
        )
        .is_err());
    }
}
