//! cpack data-layout transformation (Ding & Kennedy, PLDI'99) — paper
//! §4.1: after task partitioning, data objects are reordered by *first
//! touch* in the new schedule so that each thread block's staged loads
//! hit contiguous memory (coalesced fills, Fig 8d).
//!
//! For SPMV this permutes the x vector (columns) and y vector (rows)
//! independently; square systems (CG) use the unified variant so the
//! iteration space stays consistent.

use crate::partition::EdgePartition;

use super::coo::Coo;

/// A permutation pair: `new_of_old[i]` = new index of old index i, and
/// its inverse `old_of_new`.
#[derive(Clone, Debug)]
pub struct Perm {
    pub new_of_old: Vec<u32>,
    pub old_of_new: Vec<u32>,
}

impl Perm {
    pub fn identity(n: usize) -> Self {
        Perm {
            new_of_old: (0..n as u32).collect(),
            old_of_new: (0..n as u32).collect(),
        }
    }

    fn from_first_touch(n: usize, touches: impl Iterator<Item = u32>) -> Self {
        let mut new_of_old = vec![u32::MAX; n];
        let mut old_of_new = Vec::with_capacity(n);
        for t in touches {
            if new_of_old[t as usize] == u32::MAX {
                new_of_old[t as usize] = old_of_new.len() as u32;
                old_of_new.push(t);
            }
        }
        // untouched objects keep relative order at the end
        for i in 0..n as u32 {
            if new_of_old[i as usize] == u32::MAX {
                new_of_old[i as usize] = old_of_new.len() as u32;
                old_of_new.push(i);
            }
        }
        Perm { new_of_old, old_of_new }
    }

    /// Apply to a dense vector: out[new] = v[old].
    pub fn apply_vec<T: Copy>(&self, v: &[T]) -> Vec<T> {
        assert_eq!(v.len(), self.old_of_new.len());
        self.old_of_new.iter().map(|&o| v[o as usize]).collect()
    }

    /// Invert the application (scatter back to old order).
    pub fn unapply_vec<T: Copy>(&self, v: &[T]) -> Vec<T> {
        assert_eq!(v.len(), self.new_of_old.len());
        self.new_of_old.iter().map(|&nw| v[nw as usize]).collect()
    }

    pub fn is_valid(&self) -> bool {
        let n = self.new_of_old.len();
        self.old_of_new.len() == n
            && self
                .new_of_old
                .iter()
                .enumerate()
                .all(|(old, &nw)| self.old_of_new.get(nw as usize) == Some(&(old as u32)))
    }
}

/// Schedule order: tasks sorted by (block, original index) — the order
/// the transformed kernel walks them.
pub fn schedule_order(p: &EdgePartition) -> Vec<usize> {
    let mut order: Vec<usize> = (0..p.assign.len()).collect();
    order.sort_by_key(|&t| (p.assign[t], t as u32));
    order
}

/// cpack for SPMV: first-touch permutations of columns (x) and rows (y)
/// under the scheduled task order, plus the remapped matrix whose
/// nonzeros are also reordered into schedule order.
pub fn cpack_spmv(a: &Coo, p: &EdgePartition) -> (Coo, Perm, Perm) {
    let order = schedule_order(p);
    let col_perm =
        Perm::from_first_touch(a.ncols, order.iter().map(|&t| a.cols[t]));
    let row_perm =
        Perm::from_first_touch(a.nrows, order.iter().map(|&t| a.rows[t]));
    let mut b = Coo::new(a.nrows, a.ncols);
    for &t in &order {
        b.push(
            row_perm.new_of_old[a.rows[t] as usize] as usize,
            col_perm.new_of_old[a.cols[t] as usize] as usize,
            a.vals[t],
        );
    }
    (b, row_perm, col_perm)
}

/// cpack for a general task graph: first-touch permutation of data
/// objects under the scheduled task order (both endpoints of each task).
/// Used by the Rodinia-style application path.
pub fn cpack_graph(g: &crate::graph::Graph, p: &EdgePartition) -> Perm {
    let order = schedule_order(p);
    Perm::from_first_touch(
        g.n,
        order.iter().flat_map(|&t| {
            let (u, v) = g.edges[t];
            [u, v].into_iter()
        }),
    )
}

/// Unified cpack for square systems (CG): one permutation applied to
/// both rows and columns, built from first touch over (col, row) pairs.
pub fn cpack_square(a: &Coo, p: &EdgePartition) -> (Coo, Perm) {
    assert_eq!(a.nrows, a.ncols, "unified cpack needs a square matrix");
    let order = schedule_order(p);
    let perm = Perm::from_first_touch(
        a.ncols,
        order
            .iter()
            .flat_map(|&t| [a.cols[t], a.rows[t]].into_iter()),
    );
    let mut b = Coo::new(a.nrows, a.ncols);
    for &t in &order {
        b.push(
            perm.new_of_old[a.rows[t] as usize] as usize,
            perm.new_of_old[a.cols[t] as usize] as usize,
            a.vals[t],
        );
    }
    (b, perm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partition::default_sched::default_partition;
    use crate::sparse::gen;
    use crate::util::rng::Pcg32;

    #[test]
    fn perm_validity_and_roundtrip() {
        let a = gen::scircuit_s(500, 1);
        let p = default_partition(a.nnz(), 4);
        let (_, rp, cp) = cpack_spmv(&a, &p);
        assert!(rp.is_valid() && cp.is_valid());
        let v: Vec<f32> = (0..a.ncols).map(|i| i as f32).collect();
        assert_eq!(cp.unapply_vec(&cp.apply_vec(&v)), v);
    }

    #[test]
    fn cpack_preserves_spmv_semantics() {
        let a = gen::mac_econ_s(800, 2);
        let p = crate::partition::Method::Ep.partition(&a.affinity_graph(), 8, 3);
        let (b, rp, cp) = cpack_spmv(&a, &p);
        let mut rng = Pcg32::new(5);
        let x: Vec<f32> = (0..a.ncols).map(|_| rng.gen_f32()).collect();
        let y_direct = a.spmv(&x);
        // permuted space: x' = apply(x), y' = B x', y = unapply(y')
        let y_perm = rp.unapply_vec(&b.spmv(&cp.apply_vec(&x)));
        for (u, v) in y_direct.iter().zip(&y_perm) {
            assert!((u - v).abs() < 1e-4, "{u} vs {v}");
        }
    }

    #[test]
    fn cpack_square_preserves_semantics() {
        let a = gen::spd_poisson(20);
        let p = default_partition(a.nnz(), 4);
        let (b, perm) = cpack_square(&a, &p);
        let mut rng = Pcg32::new(9);
        let x: Vec<f32> = (0..a.ncols).map(|_| rng.gen_f32()).collect();
        let y1 = a.spmv(&x);
        let y2 = perm.unapply_vec(&b.spmv(&perm.apply_vec(&x)));
        for (u, v) in y1.iter().zip(&y2) {
            assert!((u - v).abs() < 1e-4);
        }
    }

    #[test]
    fn first_touch_makes_block_columns_contiguous() {
        let a = gen::mc2depi_s(24, 3);
        let g = a.affinity_graph();
        let p = crate::partition::Method::Ep.partition(&g, 8, 1);
        let (b, _, _) = cpack_spmv(&a, &p);
        // in the packed matrix, block 0's first task touches column 0
        assert_eq!(b.cols[0], 0);
        // and block 0's columns form a low, dense range
        let order = schedule_order(&p);
        let t0 = order.len() / p.k;
        let max_col_b0 = (0..t0).map(|t| b.cols[t]).max().unwrap();
        let uniq: std::collections::HashSet<u32> = (0..t0).map(|t| b.cols[t]).collect();
        assert!(
            (max_col_b0 as usize) < uniq.len() * 2 + 8,
            "block-0 columns not packed: max {max_col_b0}, uniq {}",
            uniq.len()
        );
    }

    #[test]
    fn untouched_objects_appended() {
        // matrix with an untouched column
        let mut a = Coo::new(2, 3);
        a.push(0, 0, 1.0);
        a.push(1, 2, 1.0);
        let p = default_partition(2, 2);
        let (_, _, cp) = cpack_spmv(&a, &p);
        assert!(cp.is_valid());
        assert_eq!(cp.new_of_old.len(), 3); // column 1 untouched but present
    }
}
