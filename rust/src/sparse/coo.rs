//! Sparse matrices: COO and CSR forms, reference SPMV, and the bridge
//! from a matrix to its SPMV data-affinity graph (paper §5.2: vertices
//! for every x_j and y_i, an edge per nonzero A[i,j] — a bipartite
//! data-affinity graph).

use crate::graph::Graph;

/// Coordinate-format sparse matrix.  Duplicate (i, j) entries are legal
/// and are summed by SPMV semantics (as in Matrix Market).
#[derive(Clone, Debug)]
pub struct Coo {
    pub nrows: usize,
    pub ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Coo {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo { nrows, ncols, rows: vec![], cols: vec![], vals: vec![] }
    }

    pub fn push(&mut self, i: usize, j: usize, v: f32) {
        debug_assert!(i < self.nrows && j < self.ncols);
        self.rows.push(i as u32);
        self.cols.push(j as u32);
        self.vals.push(v);
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Reference SPMV: y = A·x (used as the numeric oracle for the
    /// PJRT-executed kernel and by the CG fallback path).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.ncols);
        let mut y = vec![0f32; self.nrows];
        for t in 0..self.nnz() {
            y[self.rows[t] as usize] += self.vals[t] * x[self.cols[t] as usize];
        }
        y
    }

    /// Sort entries row-major (row, then col) — the CUSP-like layout.
    pub fn sort_row_major(&mut self) {
        let mut idx: Vec<usize> = (0..self.nnz()).collect();
        idx.sort_by_key(|&t| (self.rows[t], self.cols[t]));
        self.permute(&idx);
    }

    /// Reorder the nonzeros by `perm` (new position t takes old perm[t]).
    pub fn permute(&mut self, perm: &[usize]) {
        assert_eq!(perm.len(), self.nnz());
        self.rows = perm.iter().map(|&t| self.rows[t]).collect();
        self.cols = perm.iter().map(|&t| self.cols[t]).collect();
        self.vals = perm.iter().map(|&t| self.vals[t]).collect();
    }

    /// The SPMV data-affinity graph (paper §5.2): vertex ids 0..ncols are
    /// the input-vector elements x_j, ids ncols..ncols+nrows the output
    /// elements y_i; each nonzero is a task-edge (x_j, y_i).  Edge order
    /// == nonzero order, so an EdgePartition indexes nonzeros directly.
    pub fn affinity_graph(&self) -> Graph {
        let n = self.ncols + self.nrows;
        let edges = (0..self.nnz())
            .map(|t| (self.cols[t], self.ncols as u32 + self.rows[t]))
            .collect();
        Graph::from_edges(n, edges)
    }

    /// Transpose (used by SPD checks and tests).
    pub fn transpose(&self) -> Coo {
        Coo {
            nrows: self.ncols,
            ncols: self.nrows,
            rows: self.cols.clone(),
            cols: self.rows.clone(),
            vals: self.vals.clone(),
        }
    }
}

/// CSR form — used by the simulator baselines (row-split schedules).
#[derive(Clone, Debug)]
pub struct Csr {
    pub nrows: usize,
    pub ncols: usize,
    pub row_ptr: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<f32>,
}

impl Csr {
    pub fn from_coo(coo: &Coo) -> Self {
        let mut counts = vec![0u32; coo.nrows];
        for &r in &coo.rows {
            counts[r as usize] += 1;
        }
        let mut row_ptr = vec![0u32; coo.nrows + 1];
        for i in 0..coo.nrows {
            row_ptr[i + 1] = row_ptr[i] + counts[i];
        }
        let mut cursor = row_ptr[..coo.nrows].to_vec();
        let mut cols = vec![0u32; coo.nnz()];
        let mut vals = vec![0f32; coo.nnz()];
        for t in 0..coo.nnz() {
            let r = coo.rows[t] as usize;
            let at = cursor[r] as usize;
            cols[at] = coo.cols[t];
            vals[at] = coo.vals[t];
            cursor[r] += 1;
        }
        Csr { nrows: coo.nrows, ncols: coo.ncols, row_ptr, cols, vals }
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        let mut y = vec![0f32; self.nrows];
        for i in 0..self.nrows {
            let mut acc = 0f32;
            for t in self.row_ptr[i] as usize..self.row_ptr[i + 1] as usize {
                acc += self.vals[t] * x[self.cols[t] as usize];
            }
            y[i] = acc;
        }
        y
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> Coo {
        // [[1, 0, 2], [0, 3, 0]]
        let mut a = Coo::new(2, 3);
        a.push(0, 0, 1.0);
        a.push(0, 2, 2.0);
        a.push(1, 1, 3.0);
        a
    }

    #[test]
    fn coo_spmv_correct() {
        let a = small();
        assert_eq!(a.spmv(&[1.0, 2.0, 3.0]), vec![7.0, 6.0]);
    }

    #[test]
    fn duplicates_sum() {
        let mut a = Coo::new(1, 1);
        a.push(0, 0, 1.5);
        a.push(0, 0, 2.5);
        assert_eq!(a.spmv(&[2.0]), vec![8.0]);
    }

    #[test]
    fn csr_matches_coo() {
        let a = small();
        let c = Csr::from_coo(&a);
        let x = [0.5, -1.0, 4.0];
        assert_eq!(a.spmv(&x), c.spmv(&x));
    }

    #[test]
    fn affinity_graph_is_bipartite_per_nonzero() {
        let a = small();
        let g = a.affinity_graph();
        assert_eq!(g.n, 5);
        assert_eq!(g.m(), 3);
        // edge t connects x_{col} and y_{row}+ncols
        assert_eq!(g.edges[0], (0, 3));
        assert_eq!(g.edges[1], (2, 3));
        assert_eq!(g.edges[2], (1, 4));
    }

    #[test]
    fn sort_and_permute_preserve_semantics() {
        let mut a = Coo::new(3, 3);
        a.push(2, 1, 1.0);
        a.push(0, 0, 2.0);
        a.push(1, 2, 3.0);
        let x = [1.0, 1.0, 1.0];
        let before = a.spmv(&x);
        a.sort_row_major();
        assert_eq!(a.rows, vec![0, 1, 2]);
        assert_eq!(a.spmv(&x), before);
    }

    #[test]
    fn transpose_roundtrip() {
        let a = small();
        let t = a.transpose().transpose();
        assert_eq!(a.spmv(&[1.0, 2.0, 3.0]), t.spmv(&[1.0, 2.0, 3.0]));
    }
}
