//! # epgraph — edge-centric graph partitioning for GPU caching
//!
//! Production-grade reproduction of *"A Graph-based Model for GPU
//! Caching Problems"* (Li et al., 2016): the EP (balanced edge
//! partition) model for scheduling GPU tasks into thread blocks to
//! maximize shared-cache reuse, together with every substrate the
//! paper's evaluation needs — a multilevel vertex partitioner, a
//! hypergraph-partitioner baseline, PowerGraph baselines, a GPU cache /
//! memory-transaction simulator, sparse-matrix workloads, six
//! Rodinia-like application generators, and a PJRT runtime that executes
//! the AOT-compiled blocked-SPMV kernel (JAX/Pallas at build time, rust
//! on the request path).
//!
//! Layering (see DESIGN.md):
//! * L3 (this crate) — partitioning, simulation, the asynchronous
//!   optimization pipeline with adaptive overhead control, CLI/benches.
//! * L2/L1 (python/, build-time only) — the blocked-gather SPMV kernel
//!   (Pallas) inside a jax model, lowered once to `artifacts/*.hlo.txt`;
//!   `runtime::aot` emits the same artifacts directly from rust when no
//!   Python toolchain exists (`epgraph artifacts`).
//! * runtime — loads those artifacts via the PJRT surface and executes
//!   them from rust; offline the backend is the `vendor/xla` HLO-text
//!   interpreter, so the full pipeline runs (and is CI-gated) with no
//!   external dependencies.  Python never runs on the request path.
//! * service — the `epgraph serve` daemon: a content-addressed schedule
//!   cache, singleflight job queue, and worker pool that amortize
//!   optimization cost across processes and users (JSON-lines over
//!   loopback TCP; see `service::server`).

pub mod apps;
pub mod coordinator;
pub mod experiments;
pub mod gpusim;
pub mod graph;
pub mod partition;
pub mod runtime;
pub mod service;
pub mod sparse;
pub mod util;
