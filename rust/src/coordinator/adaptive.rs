//! Adaptive overhead control (paper §4.2).
//!
//! Before every kernel launch the coordinator asks the controller which
//! kernel to run.  While the optimizer is still working, the original
//! kernel runs.  The first time the transformed kernel runs, its cost is
//! recorded and compared with the original's; if it lost, the controller
//! permanently falls back ("if the first run of the transformed kernel
//! is slower, then we fall back to the original kernel in the next
//! iteration") — guaranteeing no slowdown.

/// Which kernel to launch this iteration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Choice {
    Original,
    Optimized,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum State {
    /// optimizer still running → original kernel
    Waiting,
    /// optimized schedule arrived; next launch is the recorded trial
    Trial,
    /// trial won → optimized kernel from now on
    Committed,
    /// trial lost → original kernel forever
    FellBack,
}

#[derive(Debug)]
pub struct AdaptiveController {
    state: State,
    /// running mean of original-kernel cost (cycles or ns)
    orig_cost: Option<f64>,
    orig_samples: u32,
    trial_cost: Option<f64>,
}

impl Default for AdaptiveController {
    fn default() -> Self {
        Self::new()
    }
}

impl AdaptiveController {
    pub fn new() -> AdaptiveController {
        AdaptiveController { state: State::Waiting, orig_cost: None, orig_samples: 0, trial_cost: None }
    }

    /// Decide the kernel for the next launch. `optimizer_ready` is the
    /// poll result of the async optimizer.
    pub fn choose(&mut self, optimizer_ready: bool) -> Choice {
        if self.state == State::Waiting && optimizer_ready {
            self.state = State::Trial;
        }
        match self.state {
            State::Waiting | State::FellBack => Choice::Original,
            State::Trial | State::Committed => Choice::Optimized,
        }
    }

    /// Record the measured cost of the launch just executed.
    pub fn record(&mut self, choice: Choice, cost: f64) {
        match (self.state, choice) {
            (State::Waiting | State::FellBack, Choice::Original) => {
                let n = self.orig_samples as f64;
                self.orig_cost = Some(match self.orig_cost {
                    None => cost,
                    Some(m) => (m * n + cost) / (n + 1.0),
                });
                self.orig_samples += 1;
            }
            (State::Trial, Choice::Optimized) => {
                self.trial_cost = Some(cost);
                // no original sample yet (kernel ran optimized from the
                // first launch) → trust the optimized version
                self.state = match self.orig_cost {
                    Some(orig) if cost > orig => State::FellBack,
                    _ => State::Committed,
                };
            }
            (State::Committed, Choice::Optimized) => {}
            // tolerate out-of-protocol records (e.g. warmup runs)
            _ => {}
        }
    }

    pub fn fell_back(&self) -> bool {
        self.state == State::FellBack
    }

    pub fn committed(&self) -> bool {
        self.state == State::Committed
    }

    pub fn original_cost(&self) -> Option<f64> {
        self.orig_cost
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waits_until_optimizer_ready() {
        let mut c = AdaptiveController::new();
        assert_eq!(c.choose(false), Choice::Original);
        c.record(Choice::Original, 100.0);
        assert_eq!(c.choose(false), Choice::Original);
        c.record(Choice::Original, 102.0);
        assert_eq!(c.choose(true), Choice::Optimized); // trial
    }

    #[test]
    fn commits_when_trial_wins() {
        let mut c = AdaptiveController::new();
        c.choose(false);
        c.record(Choice::Original, 100.0);
        let t = c.choose(true);
        assert_eq!(t, Choice::Optimized);
        c.record(Choice::Optimized, 60.0);
        assert!(c.committed());
        assert_eq!(c.choose(true), Choice::Optimized);
    }

    #[test]
    fn falls_back_when_trial_loses() {
        let mut c = AdaptiveController::new();
        c.choose(false);
        c.record(Choice::Original, 100.0);
        c.choose(true);
        c.record(Choice::Optimized, 150.0);
        assert!(c.fell_back());
        // permanent: stays original even though optimizer is ready
        assert_eq!(c.choose(true), Choice::Original);
        c.record(Choice::Original, 99.0);
        assert_eq!(c.choose(true), Choice::Original);
    }

    #[test]
    fn immediate_ready_trusts_optimized() {
        // optimizer finished before the first launch: no original sample;
        // the controller runs optimized and keeps it
        let mut c = AdaptiveController::new();
        assert_eq!(c.choose(true), Choice::Optimized);
        c.record(Choice::Optimized, 50.0);
        assert!(c.committed());
    }

    #[test]
    fn original_cost_averages() {
        let mut c = AdaptiveController::new();
        for cost in [100.0, 110.0, 90.0] {
            c.choose(false);
            c.record(Choice::Original, cost);
        }
        assert!((c.original_cost().unwrap() - 100.0).abs() < 1e-9);
    }
}
