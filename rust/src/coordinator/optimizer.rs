//! The data-sharing optimization pipeline (paper §4.1, Fig 9) and its
//! asynchronous wrapper (§4.2).
//!
//! Workflow: extract data-affinity graph → check reuse (degree
//! frequency) → check special patterns → EP partition → cpack layout.
//! The async wrapper runs the whole thing on a separate CPU thread — the
//! paper's exact design ("we use a separate thread for optimization to
//! prevent it from adversely affecting the performance of the main
//! program") — and the main loop polls completion before each kernel
//! launch.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use crate::graph::{stats, Graph};
use crate::partition::special::{self, Pattern};
use crate::partition::{ep, quality, EdgePartition, Method};
use crate::sparse::{cpack, Perm};

/// Tuning knobs of the optimization pipeline.
#[derive(Clone, Debug)]
pub struct OptOptions {
    /// number of thread blocks (clusters)
    pub k: usize,
    pub seed: u64,
    /// skip partitioning when avg degree ≤ threshold (paper: ≈ 2)
    pub reuse_threshold: f64,
    /// partitioning method (EP in production; baselines for benches)
    pub method: Method,
    /// enable the special-pattern shortcut
    pub use_special_patterns: bool,
    /// hard per-block task cap = thread-block size (a block of N threads
    /// runs at most N tasks); None = no physical cap
    pub block_cap: Option<usize>,
    /// partitioner engine family (PR 10): `Mode::Fm` is the quality
    /// reference and serving default; `Mode::Lp` is the data-parallel
    /// fast-miss path.  Changes the output, so it is part of the
    /// schedule-cache fingerprint.
    pub mode: crate::partition::Mode,
    /// worker threads for the partitioner's parallel phases (0 = one per
    /// core, 1 = sequential).  The optimization pipeline already runs on
    /// its own CPU thread (paper §4.2); this lets the partitioner fan
    /// out further.  Results are identical for every value.
    pub threads: usize,
}

impl Default for OptOptions {
    fn default() -> Self {
        OptOptions {
            k: 8,
            seed: 0xE9_5EED,
            reuse_threshold: 2.0,
            method: Method::Ep,
            use_special_patterns: true,
            block_cap: None,
            mode: crate::partition::Mode::Fm,
            threads: 0,
        }
    }
}

/// The pipeline's product: a schedule + layout + provenance/stats.
#[derive(Clone, Debug)]
pub struct OptimizedSchedule {
    pub partition: EdgePartition,
    /// first-touch data layout for the new schedule
    pub layout: Perm,
    /// vertex-cut cost of the partition (Definition 2)
    pub quality: u64,
    pub balance: f64,
    pub partition_time: Duration,
    /// Some(pattern) if the special-pattern shortcut fired
    pub used_special: Option<Pattern>,
    /// true if the reuse check said "don't bother" (identity schedule)
    pub skipped_low_reuse: bool,
}

/// Per-stage wall-clock breakdown of one pipeline run.  The serving
/// layer (`service`) stores this next to each cached schedule so its
/// `stats` endpoint can report where optimization time went without
/// re-running anything; `total` always equals the schedule's
/// `partition_time`.  `total` is also the entry's recompute cost in
/// the cache's eviction-aware admission policy (`service::cache`) and
/// is persisted with the schedule (`service::persist`), so the policy
/// keeps working across daemon restarts.
#[derive(Clone, Copy, Debug, Default)]
pub struct OptBreakdown {
    pub reuse_check: Duration,
    pub special_detect: Duration,
    /// Partitioner proper (EP/baseline run, or the preset-pattern build).
    pub partition: Duration,
    /// cpack first-touch relayout.
    pub layout: Duration,
    /// Vertex-cut cost accounting.
    pub quality: Duration,
    pub total: Duration,
}

/// Run the full §4.1 pipeline synchronously.
pub fn optimize_graph(g: &Graph, opts: &OptOptions) -> OptimizedSchedule {
    optimize_graph_with_breakdown(g, opts).0
}

/// The pipeline was cancelled at a stage boundary (the request's
/// deadline expired).  Carries no partial schedule: a cancelled run
/// produced nothing a caller may use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Cancelled;

/// `optimize_graph` plus its per-stage cost breakdown — the
/// cache-reusable entry point of the serving layer.  Deterministic in
/// `(g, opts)` up to `opts.threads` (results are bit-identical for every
/// thread count), which is what makes the schedule cacheable by content
/// fingerprint (`service::fingerprint`).
pub fn optimize_graph_with_breakdown(
    g: &Graph,
    opts: &OptOptions,
) -> (OptimizedSchedule, OptBreakdown) {
    optimize_graph_checked(g, opts, &|| false).expect("never-cancel run cannot be cancelled")
}

/// `optimize_graph_with_breakdown` with cooperative cancellation.  The
/// `cancel` closure is polled at every `OptBreakdown` stage boundary
/// (entry, after the reuse check, after special-pattern detection, after
/// partitioning, after relayout); once it returns true the run stops
/// with `Err(Cancelled)` instead of burning the remaining stages.  The
/// serving layer passes a deadline check here so an expired request
/// releases its worker at the next boundary.  Cancellation never changes
/// the result of a completed run — a run that returns `Ok` is
/// bit-identical to an unchecked one.
pub fn optimize_graph_checked(
    g: &Graph,
    opts: &OptOptions,
    cancel: &dyn Fn() -> bool,
) -> Result<(OptimizedSchedule, OptBreakdown), Cancelled> {
    let t0 = Instant::now();
    let mut bd = OptBreakdown::default();
    if cancel() {
        return Err(Cancelled);
    }

    // 1. reuse check: little sharing → keep the original schedule
    let t = Instant::now();
    let enough_reuse = stats::has_enough_reuse(g, opts.reuse_threshold);
    bd.reuse_check = t.elapsed();
    if !enough_reuse {
        let partition = crate::partition::default_sched::default_partition(g.m(), opts.k);
        let t = Instant::now();
        let quality = quality::vertex_cut_cost(g, &partition);
        bd.quality = t.elapsed();
        bd.total = t0.elapsed();
        let sched = OptimizedSchedule {
            layout: Perm::identity(g.n),
            balance: quality::balance_factor(&partition),
            partition,
            quality,
            partition_time: bd.total,
            used_special: None,
            skipped_low_reuse: true,
        };
        return Ok((sched, bd));
    }
    if cancel() {
        return Err(Cancelled);
    }

    // 2. special-pattern shortcut: preset schedules, no partitioner run
    if opts.use_special_patterns {
        let t = Instant::now();
        let detected = special::detect(g);
        bd.special_detect = t.elapsed();
        if cancel() {
            return Err(Cancelled);
        }
        if let Some(pat) = detected {
            let t = Instant::now();
            let mut partition = special::preset_partition(g, pat, opts.k);
            if let Some(cap) = opts.block_cap {
                ep::rebalance_to_cap(g, &mut partition, cap);
            }
            bd.partition = t.elapsed();
            let t = Instant::now();
            let layout = cpack::cpack_graph(g, &partition);
            bd.layout = t.elapsed();
            let t = Instant::now();
            let quality = quality::vertex_cut_cost(g, &partition);
            bd.quality = t.elapsed();
            bd.total = t0.elapsed();
            let sched = OptimizedSchedule {
                layout,
                balance: quality::balance_factor(&partition),
                partition,
                quality,
                partition_time: bd.total,
                used_special: Some(pat),
                skipped_low_reuse: false,
            };
            return Ok((sched, bd));
        }
    }

    // 3. the EP algorithm (or a selected baseline) + cpack relayout
    let t = Instant::now();
    let mut partition = match opts.method {
        Method::Ep => {
            let ep_opts = ep::EpOpts {
                vp: crate::partition::vertex::VpOpts {
                    seed: opts.seed,
                    threads: opts.threads,
                    mode: opts.mode,
                    ..Default::default()
                },
                ..Default::default()
            };
            ep::partition_edges(g, opts.k, &ep_opts)
        }
        other => other.partition(g, opts.k, opts.seed),
    };
    if let Some(cap) = opts.block_cap {
        ep::rebalance_to_cap(g, &mut partition, cap);
    }
    bd.partition = t.elapsed();
    if cancel() {
        return Err(Cancelled);
    }
    let t = Instant::now();
    let layout = cpack::cpack_graph(g, &partition);
    bd.layout = t.elapsed();
    if cancel() {
        return Err(Cancelled);
    }
    let t = Instant::now();
    let quality = quality::vertex_cut_cost(g, &partition);
    bd.quality = t.elapsed();
    bd.total = t0.elapsed();
    let sched = OptimizedSchedule {
        layout,
        balance: quality::balance_factor(&partition),
        partition,
        quality,
        partition_time: bd.total,
        used_special: None,
        skipped_low_reuse: false,
    };
    Ok((sched, bd))
}

/// Asynchronous optimization: the pipeline runs on its own CPU thread;
/// the GPU main loop polls `poll()` before each kernel launch and
/// switches kernels when the result arrives (paper Fig 8b).
pub struct AsyncOptimizer {
    rx: mpsc::Receiver<OptimizedSchedule>,
    started: Instant,
    result: Option<OptimizedSchedule>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl AsyncOptimizer {
    pub fn spawn(graph: Graph, opts: OptOptions) -> AsyncOptimizer {
        let (tx, rx) = mpsc::channel();
        let handle = std::thread::Builder::new()
            .name("epgraph-optimizer".into())
            .spawn(move || {
                let result = optimize_graph(&graph, &opts);
                let _ = tx.send(result); // receiver may be gone: program ended
            })
            .expect("spawn optimizer thread");
        AsyncOptimizer { rx, started: Instant::now(), result: None, handle: Some(handle) }
    }

    /// Non-blocking completion check — the "if (optimization finished)"
    /// test of Fig 8b.
    pub fn poll(&mut self) -> Option<&OptimizedSchedule> {
        if self.result.is_none() {
            if let Ok(r) = self.rx.try_recv() {
                self.result = Some(r);
            }
        }
        self.result.as_ref()
    }

    /// Block until the optimizer finishes (benches / EP-ideal mode).
    pub fn wait(&mut self) -> &OptimizedSchedule {
        if self.result.is_none() {
            if let Some(h) = self.handle.take() {
                let _ = h.join();
            }
            if let Ok(r) = self.rx.recv() {
                self.result = Some(r);
            }
        }
        self.result.as_ref().expect("optimizer thread panicked")
    }

    pub fn elapsed(&self) -> Duration {
        self.started.elapsed()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn pipeline_partitions_reusy_graphs() {
        let g = gen::cfd_mesh(30, 30, 1);
        let opts = OptOptions { k: 8, ..Default::default() };
        let r = optimize_graph(&g, &opts);
        assert!(!r.skipped_low_reuse);
        assert!(r.used_special.is_none());
        // must beat the default schedule
        let def = crate::partition::default_sched::default_partition(g.m(), 8);
        assert!(r.quality < quality::vertex_cut_cost(&g, &def));
        assert!(r.layout.is_valid());
    }

    #[test]
    fn pipeline_skips_low_reuse() {
        let g = gen::complete_bipartite(4000, 1); // star: avg degree < 2.1
        let mut opts = OptOptions { k: 8, reuse_threshold: 2.1, ..Default::default() };
        opts.use_special_patterns = false;
        let r = optimize_graph(&g, &opts);
        assert!(r.skipped_low_reuse);
        // identity layout — no data transform applied
        assert_eq!(r.layout.new_of_old[5], 5);
    }

    #[test]
    fn pipeline_uses_special_pattern() {
        let g = gen::grid_mesh(20, 20);
        let r = optimize_graph(&g, &OptOptions { k: 4, ..Default::default() });
        assert_eq!(r.used_special, Some(Pattern::Grid));
        // preset partitioning is near-instant
        assert!(r.partition_time < Duration::from_millis(50));
    }

    #[test]
    fn breakdown_totals_match_schedule() {
        let g = gen::cfd_mesh(20, 20, 1);
        let opts = OptOptions { k: 8, ..Default::default() };
        let (sched, bd) = optimize_graph_with_breakdown(&g, &opts);
        assert_eq!(bd.total, sched.partition_time);
        // stage sum can't exceed the total (stages are disjoint slices)
        let stages = bd.reuse_check + bd.special_detect + bd.partition + bd.layout + bd.quality;
        assert!(stages <= bd.total, "stages {stages:?} > total {:?}", bd.total);
        // and the run is deterministic: a second run yields the same schedule
        let again = optimize_graph(&g, &opts);
        assert_eq!(again.partition.assign, sched.partition.assign);
        assert_eq!(again.layout.new_of_old, sched.layout.new_of_old);
        assert_eq!(again.quality, sched.quality);
    }

    #[test]
    fn checked_run_matches_unchecked_and_cancels_at_entry() {
        let g = gen::cfd_mesh(20, 20, 1);
        let opts = OptOptions { k: 8, ..Default::default() };
        // cancel=false is bit-identical to the plain entry point
        let (a, _) = optimize_graph_checked(&g, &opts, &|| false).unwrap();
        let b = optimize_graph(&g, &opts);
        assert_eq!(a.partition.assign, b.partition.assign);
        assert_eq!(a.layout.new_of_old, b.layout.new_of_old);
        assert_eq!(a.quality, b.quality);
        // an already-cancelled run stops before doing any work
        assert_eq!(optimize_graph_checked(&g, &opts, &|| true).unwrap_err(), Cancelled);
    }

    #[test]
    fn cancellation_fires_at_a_later_stage_boundary() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let g = gen::cfd_mesh(20, 20, 1);
        let opts = OptOptions { k: 8, ..Default::default() };
        // let the first two boundary checks pass, then cancel: the run
        // must stop mid-pipeline instead of completing
        let polls = AtomicUsize::new(0);
        let r = optimize_graph_checked(&g, &opts, &|| {
            polls.fetch_add(1, Ordering::Relaxed) >= 2
        });
        assert_eq!(r.unwrap_err(), Cancelled);
        assert!(polls.load(Ordering::Relaxed) >= 3);
    }

    #[test]
    fn async_optimizer_delivers() {
        let g = gen::power_law(3000, 3, 5);
        let mut opt = AsyncOptimizer::spawn(g.clone(), OptOptions { k: 8, ..Default::default() });
        let r = opt.wait();
        assert_eq!(r.partition.assign.len(), g.m());
        // poll after completion keeps returning the result
        assert!(opt.poll().is_some());
    }

    #[test]
    fn async_optimizer_poll_is_nonblocking() {
        let g = gen::power_law(20000, 3, 6);
        let mut opt = AsyncOptimizer::spawn(g, OptOptions { k: 32, ..Default::default() });
        let t0 = Instant::now();
        let _ = opt.poll();
        assert!(t0.elapsed() < Duration::from_millis(50), "poll must not block");
        let _ = opt.wait();
    }
}
