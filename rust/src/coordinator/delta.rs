//! Delta optimization: the §4.1 pipeline with the partitioner stage
//! replaced by warm-start refinement (`partition::incremental`) when a
//! cached base schedule can seed it (PR 9).
//!
//! `optimize_delta_checked` mirrors `optimize_graph_checked` stage for
//! stage — same reuse check, same special-pattern shortcut, same
//! layout/quality accounting, cancellation polled at the same
//! boundaries — so a completed delta run yields a full-fledged
//! `OptimizedSchedule` the serving layer caches under the post-delta
//! graph's own content fingerprint, indistinguishable in shape from a
//! cold run.  Only the partition stage differs: when the base schedule
//! is a genuine EP partition with the requested block count, the cached
//! assignment seeds `incremental::refine_from`; otherwise (preset
//! pattern, low-reuse identity schedule, baseline method, k mismatch)
//! the stage falls back to the full partitioner, because those bases
//! carry nothing worth refining.
//!
//! The refined schedule is NOT defined to be bit-identical to a cold
//! run on the same graph — warm-start and cold-start may settle in
//! different local optima of comparable cut.  What IS guaranteed:
//! same base + same delta ⇒ bit-identical result for any thread count
//! (the cache layer's singleflight then makes the *served* bytes for
//! one fingerprint identical regardless of which path computed them).

use std::time::Instant;

use crate::graph::{stats, Graph};
use crate::partition::{ep, incremental, quality, Method};
use crate::sparse::cpack;

use super::optimizer::{Cancelled, OptBreakdown, OptOptions, OptimizedSchedule};

/// Can `base` seed warm-start refinement for a request with `opts`?
/// Public so the serving layer can report which path a reply took.
pub fn refinable(base: &OptimizedSchedule, opts: &OptOptions) -> bool {
    opts.method == Method::Ep
        && !base.skipped_low_reuse
        && base.used_special.is_none()
        && base.partition.k == opts.k
}

/// `optimize_graph` for a delta request: refine `base` onto `post` (the
/// post-delta graph) instead of partitioning from scratch.
/// `new_of_old_edge` is the edge-id map from `graph::delta::apply_delta`.
pub fn optimize_delta(
    base: &OptimizedSchedule,
    post: &Graph,
    new_of_old_edge: &[u32],
    opts: &OptOptions,
) -> (OptimizedSchedule, OptBreakdown) {
    optimize_delta_checked(base, post, new_of_old_edge, opts, &|| false)
        .expect("never-cancel run cannot be cancelled")
}

/// `optimize_delta` with cooperative cancellation at the same stage
/// boundaries as `optimize_graph_checked`.
pub fn optimize_delta_checked(
    base: &OptimizedSchedule,
    post: &Graph,
    new_of_old_edge: &[u32],
    opts: &OptOptions,
    cancel: &dyn Fn() -> bool,
) -> Result<(OptimizedSchedule, OptBreakdown), Cancelled> {
    let t0 = Instant::now();
    let mut bd = OptBreakdown::default();
    if cancel() {
        return Err(Cancelled);
    }

    // 1./2. reuse check and special-pattern shortcut behave exactly as
    // in a cold run — if either fires on the post-delta graph, the
    // result must match what an inline request would have produced, so
    // delegate the whole remainder to the cold pipeline (its own entry
    // cancel check is a no-op we already passed).
    let t = Instant::now();
    let enough_reuse = stats::has_enough_reuse(post, opts.reuse_threshold);
    bd.reuse_check = t.elapsed();
    if cancel() {
        return Err(Cancelled);
    }
    let special_hit = if opts.use_special_patterns {
        let t = Instant::now();
        let detected = crate::partition::special::detect(post);
        bd.special_detect = t.elapsed();
        detected.is_some()
    } else {
        false
    };
    if cancel() {
        return Err(Cancelled);
    }
    if !enough_reuse || special_hit || !refinable(base, opts) {
        // shortcut fired or the base can't seed refinement — run the
        // cold pipeline (it redoes the two cheap checks; their cost is
        // noise next to the partition stage it decides about)
        return super::optimizer::optimize_graph_checked(post, opts, cancel);
    }

    // 3. warm-start partition stage: seed from the base, boundary-FM
    let t = Instant::now();
    let ep_opts = ep::EpOpts {
        vp: crate::partition::vertex::VpOpts {
            seed: opts.seed,
            threads: opts.threads,
            mode: opts.mode,
            ..Default::default()
        },
        ..Default::default()
    };
    let mut partition = incremental::refine_from(&base.partition, new_of_old_edge, post, &ep_opts);
    if let Some(cap) = opts.block_cap {
        ep::rebalance_to_cap(post, &mut partition, cap);
    }
    bd.partition = t.elapsed();
    if cancel() {
        return Err(Cancelled);
    }
    let t = Instant::now();
    let layout = cpack::cpack_graph(post, &partition);
    bd.layout = t.elapsed();
    if cancel() {
        return Err(Cancelled);
    }
    let t = Instant::now();
    let quality = quality::vertex_cut_cost(post, &partition);
    bd.quality = t.elapsed();
    bd.total = t0.elapsed();
    let sched = OptimizedSchedule {
        layout,
        balance: quality::balance_factor(&partition),
        partition,
        quality,
        partition_time: bd.total,
        used_special: None,
        skipped_low_reuse: false,
    };
    Ok((sched, bd))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::optimize_graph;
    use crate::graph::delta::{apply_delta, EdgeDelta};
    use crate::graph::gen;

    fn setup(k: usize) -> (Graph, OptimizedSchedule, OptOptions) {
        let g = gen::cfd_mesh(30, 30, 1);
        let opts = OptOptions { k, ..Default::default() };
        let base = optimize_graph(&g, &opts);
        (g, base, opts)
    }

    fn delta(g: &Graph) -> EdgeDelta {
        EdgeDelta {
            add_edges: vec![(0, 7), (11, 200)],
            remove_edges: vec![g.edges[1], g.edges[g.m() / 2]],
        }
    }

    #[test]
    fn delta_run_produces_a_full_schedule() {
        let (g, base, opts) = setup(8);
        let (post, map) = apply_delta(&g, &delta(&g)).unwrap();
        let (sched, bd) = optimize_delta(&base, &post, &map, &opts);
        assert_eq!(sched.partition.assign.len(), post.m());
        assert!(sched.layout.is_valid());
        assert!(!sched.skipped_low_reuse);
        assert!(sched.used_special.is_none());
        assert_eq!(bd.total, sched.partition_time);
        // quality within sight of a cold run on the same graph
        let cold = optimize_graph(&post, &opts);
        assert!(
            (sched.quality as f64) <= (cold.quality as f64) * 1.25 + 4.0,
            "delta quality {} vs cold {}",
            sched.quality,
            cold.quality
        );
    }

    #[test]
    fn delta_run_is_deterministic_across_threads() {
        let (g, base, opts) = setup(6);
        let (post, map) = apply_delta(&g, &delta(&g)).unwrap();
        let o1 = OptOptions { threads: 1, ..opts.clone() };
        let om = OptOptions { threads: 0, ..opts.clone() };
        let (a, _) = optimize_delta(&base, &post, &map, &o1);
        let (b, _) = optimize_delta(&base, &post, &map, &om);
        assert_eq!(a.partition.assign, b.partition.assign);
        assert_eq!(a.layout.new_of_old, b.layout.new_of_old);
        assert_eq!(a.quality, b.quality);
    }

    #[test]
    fn unrefinable_base_falls_back_to_cold_pipeline() {
        let (g, base, opts) = setup(8);
        let (post, map) = apply_delta(&g, &delta(&g)).unwrap();
        // k mismatch: the cached 8-way assignment can't seed a 4-way run
        let opts4 = OptOptions { k: 4, ..opts.clone() };
        assert!(!refinable(&base, &opts4));
        let (warm, _) = optimize_delta(&base, &post, &map, &opts4);
        let cold = optimize_graph(&post, &opts4);
        assert_eq!(warm.partition.assign, cold.partition.assign);
        assert_eq!(warm.quality, cold.quality);
    }

    #[test]
    fn shortcut_stages_match_inline_requests() {
        // a post graph that trips the special-pattern shortcut must
        // produce exactly what an inline request would
        let g = gen::grid_mesh(20, 20);
        let opts = OptOptions { k: 4, ..Default::default() };
        let base = optimize_graph(&g, &opts);
        // removing and re-adding the same edge keeps the grid a grid
        let e = g.edges[5];
        let d = EdgeDelta { add_edges: vec![e], remove_edges: vec![e] };
        let (post, map) = apply_delta(&g, &d).unwrap();
        let (warm, _) = optimize_delta(&base, &post, &map, &opts);
        let cold = optimize_graph(&post, &opts);
        assert_eq!(warm.used_special, cold.used_special);
        assert_eq!(warm.partition.assign, cold.partition.assign);
    }

    #[test]
    fn cancellation_respects_stage_boundaries() {
        let (g, base, opts) = setup(8);
        let (post, map) = apply_delta(&g, &delta(&g)).unwrap();
        assert_eq!(
            optimize_delta_checked(&base, &post, &map, &opts, &|| true).unwrap_err(),
            Cancelled
        );
    }
}
