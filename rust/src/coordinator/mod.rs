//! The L3 coordination layer: the paper's runtime system (Section 4).
//!
//! * `optimizer` — the §4.1 pipeline (graph → reuse check → special
//!   patterns → EP partition → cpack) and its asynchronous CPU-thread
//!   wrapper.
//! * `adaptive` — §4.2 adaptive overhead control (trial + fallback).
//! * `cg` — the end-to-end conjugate-gradient driver wiring PJRT
//!   execution, the optimizer, and the GPU simulator together.
//! * `splitting` — §4.2 kernel splitting for single-launch kernels.
//! * `delta` — the pipeline with a warm-start partition stage for
//!   dynamic-graph (edge-delta) requests (PR 9).

pub mod adaptive;
pub mod cg;
pub mod delta;
pub mod optimizer;
pub mod splitting;

pub use adaptive::{AdaptiveController, Choice};
pub use cg::{run_cg, CgReport, CgRunConfig};
pub use delta::{optimize_delta, optimize_delta_checked};
pub use optimizer::{
    optimize_graph, optimize_graph_checked, optimize_graph_with_breakdown, AsyncOptimizer,
    Cancelled, OptBreakdown, OptOptions, OptimizedSchedule,
};
pub use splitting::{auto_splits, run_with_splitting, run_with_splitting_at, SplitReport};
