//! End-to-end CG driver (paper §5.2): iterate SPMV inside conjugate
//! gradient with asynchronous data-sharing optimization and adaptive
//! overhead control, numerics executed by the AOT PJRT kernel and GPU
//! behaviour tracked by the transaction simulator.
//!
//! This is the paper's EP-adapt configuration; `wait_for_optimizer`
//! gives EP-ideal (partition cost paid up front, all iterations
//! optimized).

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::gpusim::{sim_blocked_launch, sim_rowsplit, GpuConfig, SimResult};
use crate::partition::{default_sched, quality, EdgePartition, Method};
use crate::runtime::{CgExec, Engine};
use crate::sparse::{cpack, pack_blocked, BlockedShape, Coo};

use super::adaptive::{AdaptiveController, Choice};
use super::optimizer::{AsyncOptimizer, OptOptions};

#[derive(Clone, Debug)]
pub struct CgRunConfig {
    /// tasks (nonzeros) per thread block — paper default 1024
    pub block_size: usize,
    pub tol: f32,
    pub max_iters: usize,
    pub gpu: GpuConfig,
    pub method: Method,
    /// EP-ideal: block until the optimizer finishes before iterating
    pub wait_for_optimizer: bool,
    pub seed: u64,
}

impl Default for CgRunConfig {
    fn default() -> Self {
        CgRunConfig {
            block_size: 1024,
            tol: 1e-4,
            max_iters: 400,
            gpu: GpuConfig::default(),
            method: Method::Ep,
            wait_for_optimizer: false,
            seed: 0x5EED,
        }
    }
}

#[derive(Debug)]
pub struct CgReport {
    pub iterations: usize,
    pub residual: f32,
    /// iteration index at which the optimized kernel took over
    pub switched_at: Option<usize>,
    pub fell_back: bool,
    pub partition_time: Duration,
    pub wall_time: Duration,
    /// simulated per-iteration kernel cost, original schedule
    pub sim_original: SimResult,
    /// simulated per-iteration kernel cost, optimized schedule
    pub sim_optimized: Option<SimResult>,
    /// total simulated cycles across all iterations actually run
    pub sim_cycles_total: u64,
    /// vertex-cut quality: default vs optimized schedule
    pub quality_default: u64,
    pub quality_optimized: Option<u64>,
    pub solution: Vec<f32>,
}

impl CgReport {
    /// Simulated speedup of optimized vs original per-iteration kernel.
    pub fn kernel_speedup(&self) -> Option<f64> {
        self.sim_optimized
            .as_ref()
            .map(|o| self.sim_original.cycles as f64 / o.cycles.max(1) as f64)
    }
}

/// Shape big enough for a's packing under partition p.
fn fitting_shape(a: &Coo, p: &EdgePartition) -> BlockedShape {
    let mut counts = vec![0usize; p.k];
    for &b in &p.assign {
        counts[b as usize] += 1;
    }
    let e = counts.iter().copied().max().unwrap_or(1);
    let n = a.nrows.max(a.ncols);
    BlockedShape { n_in: n, n_out: n, k: p.k, e, c: e }
}

/// Run CG with the full pipeline.  `a` must be square SPD.
pub fn run_cg(engine: &mut Engine, a: &Coo, rhs: &[f32], cfg: &CgRunConfig) -> Result<CgReport> {
    anyhow::ensure!(a.nrows == a.ncols, "CG needs a square system");
    let t_start = Instant::now();
    let k = a.nnz().div_ceil(cfg.block_size).max(1);

    // --- original kernel: default contiguous schedule, no relayout ---
    let p_default = default_sched::default_partition(a.nnz(), k);
    let g = a.affinity_graph();
    let quality_default = quality::vertex_cut_cost(&g, &p_default);
    let packed_orig = pack_blocked(a, &p_default, fitting_shape(a, &p_default))?;
    let cg_orig = CgExec::prepare(engine, &packed_orig)?;
    // simulated baseline: CUSPARSE-like row-split through texture cache
    let sim_original = {
        let mut sorted = a.clone();
        sorted.sort_row_major();
        sim_rowsplit(&cfg.gpu, &sorted, cfg.block_size, true)
    };

    // --- spawn the optimizer on its own CPU thread ---
    let opt_opts = OptOptions {
        k,
        seed: cfg.seed,
        method: cfg.method,
        block_cap: Some(cfg.block_size),
        ..Default::default()
    };
    let mut optimizer = AsyncOptimizer::spawn(g, opt_opts);
    if cfg.wait_for_optimizer {
        optimizer.wait();
    }

    // --- iterate ---
    let mut controller = AdaptiveController::new();
    let mut st = cg_orig.init(rhs);
    let mut in_permuted_space = false;
    let mut opt_kernel: Option<(CgExec, cpack::Perm, SimResult, u64)> = None;
    let mut switched_at = None;
    let mut partition_time = Duration::ZERO;
    let mut sim_cycles_total = 0u64;
    let tol2 = cfg.tol * cfg.tol;

    while st.rz > tol2 && st.iterations < cfg.max_iters {
        // build the optimized kernel when the schedule arrives
        if opt_kernel.is_none() {
            if let Some(sched) = optimizer.poll() {
                let sched = sched.clone();
                partition_time = sched.partition_time;
                let t_pack = Instant::now();
                let (a_packed, perm) = cpack::cpack_square(a, &sched.partition);
                let order = cpack::schedule_order(&sched.partition);
                let p2 = EdgePartition::new(
                    sched.partition.k,
                    order.iter().map(|&t| sched.partition.assign[t]).collect(),
                );
                let blocked = pack_blocked(&a_packed, &p2, fitting_shape(&a_packed, &p2))?;
                let exec = CgExec::prepare(engine, &blocked)?;
                let sim = sim_blocked_launch(&cfg.gpu, &blocked, true, cfg.block_size);
                partition_time += t_pack.elapsed();
                opt_kernel = Some((exec, perm, sim, sched.quality));
            }
        }

        let choice = controller.choose(opt_kernel.is_some());
        match choice {
            Choice::Original => {
                if in_permuted_space {
                    // fell back mid-flight: restore original space
                    let (_, perm, _, _) = opt_kernel.as_ref().unwrap();
                    st.x = perm.unapply_vec(&st.x);
                    st.r = perm.unapply_vec(&st.r);
                    st.p = perm.unapply_vec(&st.p);
                    in_permuted_space = false;
                }
                cg_orig.step(&mut st)?;
                controller.record(choice, sim_original.cycles as f64);
                sim_cycles_total += sim_original.cycles;
            }
            Choice::Optimized => {
                let (exec, perm, sim, _) = opt_kernel.as_ref().unwrap();
                if !in_permuted_space {
                    st.x = perm.apply_vec(&st.x);
                    st.r = perm.apply_vec(&st.r);
                    st.p = perm.apply_vec(&st.p);
                    in_permuted_space = true;
                    switched_at = Some(st.iterations);
                }
                exec.step(&mut st)?;
                controller.record(choice, sim.cycles as f64);
                sim_cycles_total += sim.cycles;
            }
        }
    }

    // land the solution back in original index space
    let mut solution = st.x.clone();
    if in_permuted_space {
        let (_, perm, _, _) = opt_kernel.as_ref().unwrap();
        solution = perm.unapply_vec(&solution);
    }
    if controller.fell_back() {
        switched_at = None;
    }

    Ok(CgReport {
        iterations: st.iterations,
        residual: st.rz.sqrt(),
        switched_at,
        fell_back: controller.fell_back(),
        partition_time,
        wall_time: t_start.elapsed(),
        sim_original,
        sim_optimized: opt_kernel.as_ref().map(|(_, _, s, _)| s.clone()),
        sim_cycles_total,
        quality_default,
        quality_optimized: opt_kernel.as_ref().map(|(_, _, _, q)| *q),
        solution,
    })
}
