//! Kernel splitting (paper §4.2): when a kernel is launched only once,
//! there is no later launch to apply the asynchronous optimization to —
//! so the single launch is split into several smaller launches of the
//! same kernel, and chunks that start *after* the optimizer finishes use
//! the optimized schedule.
//!
//! This runs at the simulation level: chunk durations come from the
//! transaction simulator's cycle model (1 cycle ≙ 1 ns at the modelled
//! 1 GHz core clock), while the optimizer's duration is its measured
//! wall time — the same clock-domain mix the real system deals with.

use std::time::Duration;

use crate::gpusim::{sim_original, sim_task_graph, GpuConfig};
use crate::graph::Graph;
use crate::sparse::cpack;

use super::optimizer::{optimize_graph, OptOptions};

#[derive(Debug)]
pub struct SplitReport {
    pub splits: usize,
    /// chunks that ran with the original schedule
    pub chunks_original: usize,
    /// chunks that ran optimized
    pub chunks_optimized: usize,
    /// simulated total kernel time (ns ≙ cycles)
    pub total_cycles: u64,
    /// simulated time had the kernel run unsplit/unoptimized
    pub baseline_cycles: u64,
    pub partition_time: Duration,
}

impl SplitReport {
    pub fn speedup(&self) -> f64 {
        self.baseline_cycles as f64 / self.total_cycles.max(1) as f64
    }
}

/// Split one launch of a task-graph kernel into `splits` sequential
/// chunk launches, optimizing concurrently (optimizer duration is
/// measured wall time).
pub fn run_with_splitting(
    gpu: &GpuConfig,
    g: &Graph,
    block_size: usize,
    splits: usize,
    opts: &OptOptions,
) -> SplitReport {
    run_with_splitting_at(gpu, g, block_size, splits, opts, None)
}

/// As `run_with_splitting`, but with an injectable optimizer duration —
/// used by benches/tests to replay the overlap at a modelled GPU:CPU
/// speed ratio instead of this host's (the paper's kernels are seconds
/// long; our simulated laptop-scale kernels are microseconds).
pub fn run_with_splitting_at(
    gpu: &GpuConfig,
    g: &Graph,
    block_size: usize,
    splits: usize,
    opts: &OptOptions,
    opt_time_override: Option<Duration>,
) -> SplitReport {
    let m = g.m();
    let splits = splits.max(1);
    let chunk_tasks = m.div_ceil(splits);
    let baseline_cycles = sim_original(gpu, g, block_size).cycles;

    // run the optimizer synchronously but *measure* it, then replay the
    // overlap: chunks whose simulated start time precedes the measured
    // optimizer completion run with the original schedule
    let mut sched = optimize_graph(g, opts);
    if let Some(t) = opt_time_override {
        sched.partition_time = t;
    }
    let opt_done_ns = sched.partition_time.as_nanos() as u64;

    // pre-simulate the optimized whole-kernel to get per-task rates
    let k_opt = m.div_ceil(block_size).max(1);
    let sub_opt = {
        let layout = cpack::cpack_graph(g, &sched.partition);
        sim_task_graph(gpu, g, &sched.partition, Some(&layout), true)
    };
    let opt_cycles_per_task = sub_opt.cycles as f64 / m.max(1) as f64;
    let _ = k_opt;

    let mut clock_ns = 0u64;
    let mut total_cycles = 0u64;
    let mut chunks_original = 0usize;
    let mut chunks_optimized = 0usize;
    for s in 0..splits {
        let lo = s * chunk_tasks;
        let hi = ((s + 1) * chunk_tasks).min(m);
        if lo >= hi {
            break;
        }
        let chunk_len = hi - lo;
        let cycles = if clock_ns >= opt_done_ns {
            chunks_optimized += 1;
            (opt_cycles_per_task * chunk_len as f64) as u64
        } else {
            chunks_original += 1;
            // chunk subgraph under the original schedule
            let sub = Graph::from_edges(g.n, g.edges[lo..hi].to_vec());
            sim_original(gpu, &sub, block_size).cycles
        };
        clock_ns += cycles; // 1 GHz: cycles ≙ ns
        total_cycles += cycles;
    }

    SplitReport {
        splits,
        chunks_original,
        chunks_optimized,
        total_cycles,
        baseline_cycles,
        partition_time: sched.partition_time,
    }
}

/// Choose a split count so that early chunks cover the expected
/// optimization time: the paper splits so optimization overlaps roughly
/// the first half of the work.
pub fn auto_splits(gpu: &GpuConfig, g: &Graph, block_size: usize, expected_opt: Duration) -> usize {
    let total = sim_original(gpu, g, block_size).cycles; // ns at 1 GHz
    let opt_ns = expected_opt.as_nanos() as u64;
    if opt_ns == 0 || total == 0 {
        return 2;
    }
    // want chunk duration ≈ opt time → splits ≈ total / opt, clamped
    ((total / opt_ns.max(1)).clamp(2, 64)) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn splitting_applies_optimization_partway() {
        let gpu = GpuConfig::default();
        let g = gen::cfd_mesh(60, 60, 1);
        let opts = OptOptions { k: g.m().div_ceil(256), ..Default::default() };
        // model a paper-scale ratio: optimization finishes ~30% into the
        // kernel (the measured host wall-time is replaced, not the work)
        let base = sim_original(&gpu, &g, 256).cycles;
        let opt_t = Duration::from_nanos(base * 3 / 10);
        let r = run_with_splitting_at(&gpu, &g, 256, 8, &opts, Some(opt_t));
        assert_eq!(r.chunks_original + r.chunks_optimized, 8);
        assert!(r.chunks_optimized >= 1, "{r:?}");
        assert!(r.chunks_original >= 1, "{r:?}");
        assert!(r.total_cycles > 0);
        // optimized tail must beat the unsplit baseline
        assert!(r.speedup() > 0.9, "{r:?}");
    }

    #[test]
    fn split_chunks_cover_all_tasks_cycles() {
        let gpu = GpuConfig::default();
        let g = gen::grid_mesh(40, 40);
        let opts = OptOptions { k: 8, ..Default::default() };
        let a = run_with_splitting(&gpu, &g, 256, 1, &opts);
        // 1 split = no overlap possible → pure original
        assert_eq!(a.chunks_original, 1);
        assert_eq!(a.chunks_optimized, 0);
    }

    #[test]
    fn auto_splits_reasonable() {
        let gpu = GpuConfig::default();
        let g = gen::cfd_mesh(40, 40, 2);
        let s = auto_splits(&gpu, &g, 256, Duration::from_micros(50));
        assert!((2..=64).contains(&s));
    }
}
