//! Experiment harnesses — one function per table/figure of the paper's
//! evaluation (see DESIGN.md §5 for the index).  Each returns printable
//! rows; `main.rs` exposes them as `epgraph bench <exp>` and the
//! `benches/` targets re-run them under `cargo bench`.
//!
//! Shape expectations (paper → here) are documented per function and
//! recorded against measurements in EXPERIMENTS.md.

use std::time::{Duration, Instant};

use crate::apps::{self, CacheType};
use crate::gpusim::{sim_blocked_launch, sim_original, sim_rowsplit, sim_task_graph_launch, GpuConfig, SimResult};
use crate::graph::{stats, Graph};
use crate::partition::{
    default_sched, ep, hypergraph, quality, vertex::VpOpts, EdgePartition, Method,
};
use crate::sparse::{cpack, gen, pack_blocked, BlockedShape, Coo};
use crate::util::benchkit::Table;

/// Default tasks-per-block used across the SPMV experiments (paper: 1024).
pub const BLOCK_SIZE: usize = 1024;
/// Modelled CG iteration count for the adaptive replays (paper's CG runs
/// "until convergence"; hundreds of iterations is typical).
pub const CG_ITERS: u64 = 300;

fn k_for(m: usize, block: usize) -> usize {
    m.div_ceil(block).max(1)
}

// ---------------------------------------------------------------- fig 4/5

pub fn fig4_degree(seed: u64) -> Table {
    let mut t = Table::new(&["graph", "n", "m", "avg_deg", "d_max", "top degrees (deg:count)", "loglog_slope"]);
    for (name, m) in gen::fig6_suite(seed) {
        let g = m.affinity_graph();
        let dist = stats::degree_distribution(&g);
        let mut top: Vec<_> = dist.iter().collect();
        top.sort_by_key(|p| std::cmp::Reverse(p.count));
        let tops = top
            .iter()
            .take(4)
            .map(|p| format!("{}:{}", p.degree, p.count))
            .collect::<Vec<_>>()
            .join(" ");
        let slope = stats::log_log_slope(&g)
            .map(|s| format!("{s:.2}"))
            .unwrap_or_else(|| "n/a".into());
        t.row(&[
            name.to_string(),
            g.n.to_string(),
            g.m().to_string(),
            format!("{:.2}", g.avg_degree()),
            g.max_degree().to_string(),
            tops,
            slope,
        ]);
    }
    t
}

// ------------------------------------------------------------------ fig 6

pub struct Fig6Row {
    pub name: String,
    pub n: usize,
    pub m: usize,
    pub default_q: u64,
    pub hp_time: Duration,
    pub hp_q: u64,
    pub random_q: u64,
    pub greedy_q: u64,
    pub ep_time: Duration,
    pub ep_q: u64,
}

/// Fig 6: EP vs hypergraph vs PowerGraph vs default on five graphs.
/// Expected shape: EP ≈ HP quality at a fraction of the time; random
/// and greedy far worse than default.
pub fn fig6_partition(seed: u64) -> Vec<Fig6Row> {
    let mut rows = Vec::new();
    for (name, mat) in gen::fig6_suite(seed) {
        let g = mat.affinity_graph();
        let k = k_for(g.m(), BLOCK_SIZE);
        let q = |p: &EdgePartition| quality::vertex_cut_cost(&g, p);

        let default_q = q(&default_sched::default_partition(g.m(), k));
        let random_q = q(&Method::PgRandom.partition(&g, k, seed));
        let greedy_q = q(&Method::PgGreedy.partition(&g, k, seed));
        let t0 = Instant::now();
        let hp = hypergraph::partition_edges(&g, k, &hypergraph::HpOpts { seed, ..Default::default() });
        let hp_time = t0.elapsed();
        let hp_q = q(&hp);
        let t1 = Instant::now();
        let epp = {
            let o = ep::EpOpts { vp: VpOpts { seed, ..Default::default() }, ..Default::default() };
            ep::partition_edges(&g, k, &o)
        };
        let ep_time = t1.elapsed();
        let ep_q = q(&epp);
        rows.push(Fig6Row {
            name: name.to_string(),
            n: g.n,
            m: g.m(),
            default_q,
            hp_time,
            hp_q,
            random_q,
            greedy_q,
            ep_time,
            ep_q,
        });
    }
    rows
}

pub fn fig6_table(rows: &[Fig6Row]) -> Table {
    let mut t = Table::new(&[
        "matrix", "#vertices", "#edges", "default q", "HP time", "HP q", "random q", "greedy q",
        "EP time", "EP q", "EP/HP time",
    ]);
    for r in rows {
        t.row(&[
            r.name.clone(),
            r.n.to_string(),
            r.m.to_string(),
            r.default_q.to_string(),
            format!("{:.3}s", r.hp_time.as_secs_f64()),
            r.hp_q.to_string(),
            r.random_q.to_string(),
            r.greedy_q.to_string(),
            format!("{:.3}s", r.ep_time.as_secs_f64()),
            r.ep_q.to_string(),
            format!("{:.1}x", r.hp_time.as_secs_f64() / r.ep_time.as_secs_f64().max(1e-9)),
        ]);
    }
    t
}

// ----------------------------------------------- SPMV kernels (tbl2, fig10-12)

/// Everything the SPMV experiments need for one matrix.
pub struct SpmvCase {
    pub name: String,
    pub nnz: usize,
    pub dim: usize,
    /// simulated per-SPMV results
    pub cusparse: SimResult,
    pub cusp: SimResult,
    pub ep_smem: SimResult,
    pub ep_tex: SimResult,
    pub hp_smem: SimResult,
    pub ep_partition_time: Duration,
    pub hp_partition_time: Duration,
    pub ep_quality: u64,
    pub default_quality: u64,
}

fn blocked_for(a: &Coo, p: &EdgePartition, block_cap: usize) -> crate::sparse::BlockedSpmv {
    // enforce the physical thread-block cap, then cpack relayout +
    // reorder the assignment into schedule order
    let mut p = p.clone();
    ep::rebalance_to_cap(&a.affinity_graph(), &mut p, block_cap);
    let (packed, _, _) = cpack::cpack_spmv(a, &p);
    let order = cpack::schedule_order(&p);
    let p2 = EdgePartition::new(p.k, order.iter().map(|&t| p.assign[t]).collect());
    let n = a.nrows.max(a.ncols);
    pack_blocked(
        &packed,
        &p2,
        BlockedShape { n_in: n, n_out: n, k: p2.k, e: block_cap, c: block_cap },
    )
    .expect("packing under the rebalanced partition always fits")
}

/// Run the full SPMV kernel matrix for one input, at one block size.
pub fn spmv_case(gpu: &GpuConfig, name: &str, a: &Coo, block: usize, seed: u64) -> SpmvCase {
    let mut sorted = a.clone();
    sorted.sort_row_major();
    let g = a.affinity_graph();
    let k = k_for(a.nnz(), block);

    let cusparse = sim_rowsplit(gpu, &sorted, block, true);
    let cusp = sim_rowsplit(gpu, &sorted, block, false);

    let t0 = Instant::now();
    let ep_p = {
        let o = ep::EpOpts { vp: VpOpts { seed, ..Default::default() }, ..Default::default() };
        ep::partition_edges(&g, k, &o)
    };
    let ep_partition_time = t0.elapsed();
    let ep_quality = quality::vertex_cut_cost(&g, &ep_p);
    let default_quality =
        quality::vertex_cut_cost(&g, &default_sched::default_partition(g.m(), k));
    let ep_blocked = blocked_for(a, &ep_p, block);
    let ep_smem = sim_blocked_launch(gpu, &ep_blocked, true, block);
    let ep_tex = sim_blocked_launch(gpu, &ep_blocked, false, block);

    let t1 = Instant::now();
    let hp_p = hypergraph::partition_edges(
        &g,
        k,
        &hypergraph::HpOpts { seed, ..Default::default() },
    );
    let hp_partition_time = t1.elapsed();
    let hp_blocked = blocked_for(a, &hp_p, block);
    let hp_smem = sim_blocked_launch(gpu, &hp_blocked, true, block);

    SpmvCase {
        name: name.to_string(),
        nnz: a.nnz(),
        dim: a.nrows,
        cusparse,
        cusp,
        ep_smem,
        ep_tex,
        hp_smem,
        ep_partition_time,
        hp_partition_time,
        ep_quality,
        default_quality,
    }
}

pub fn table2_cases(gpu: &GpuConfig, seed: u64) -> Vec<SpmvCase> {
    gen::paper_suite(seed)
        .iter()
        .map(|(name, a)| spmv_case(gpu, name, a, BLOCK_SIZE, seed))
        .collect()
}

/// Table 2: per-matrix kernel + partition costs.  Kernel "time" is
/// simulated cycles × CG_ITERS (the paper reports whole-CG totals).
pub fn table2_table(cases: &[SpmvCase]) -> Table {
    let mut t = Table::new(&[
        "name", "dim", "nnz", "CUSPARSE cyc", "EP cyc", "EP partition", "HP cyc", "HP partition",
        "EP part %", "HP part %",
    ]);
    for c in cases {
        // partition overhead as % of total CUSPARSE kernel time, at the
        // modelled 1 GHz clock (cycles ≙ ns)
        let total_ns = (c.cusparse.cycles * CG_ITERS) as f64;
        let ep_pct = c.ep_partition_time.as_nanos() as f64 / total_ns * 100.0;
        let hp_pct = c.hp_partition_time.as_nanos() as f64 / total_ns * 100.0;
        t.row(&[
            c.name.clone(),
            c.dim.to_string(),
            c.nnz.to_string(),
            (c.cusparse.cycles * CG_ITERS).to_string(),
            (c.ep_smem.cycles * CG_ITERS).to_string(),
            format!("{:.3}s", c.ep_partition_time.as_secs_f64()),
            (c.hp_smem.cycles * CG_ITERS).to_string(),
            format!("{:.3}s", c.hp_partition_time.as_secs_f64()),
            format!("{ep_pct:.0}%"),
            format!("{hp_pct:.0}%"),
        ]);
    }
    t
}

/// EP-adapt replay: CG_ITERS iterations; iterations before the
/// optimizer's (converted) completion run the original kernel.
pub fn adapt_cycles(orig: u64, opt: u64, partition: Duration, iters: u64) -> u64 {
    let part_ns = partition.as_nanos() as u64; // 1 cycle ≙ 1 ns
    let mut total = 0u64;
    let mut clock = 0u64;
    let mut remaining = iters;
    // original until the optimizer is done
    while clock < part_ns && remaining > 0 {
        total += orig;
        clock += orig;
        remaining -= 1;
    }
    // trial + committed (or fallback if opt loses)
    if remaining > 0 {
        if opt > orig {
            total += opt; // one losing trial
            remaining -= 1;
            total += remaining * orig;
        } else {
            total += remaining * opt;
        }
    }
    total
}

/// Fig 10: speedup over CUSPARSE for CUSP, EP-ideal, EP-adapt.
pub fn fig10_table(cases: &[SpmvCase]) -> Table {
    let mut t = Table::new(&["name", "CUSP", "EP-ideal", "EP-adapt"]);
    for c in cases {
        let base = (c.cusparse.cycles * CG_ITERS) as f64;
        let cusp = base / (c.cusp.cycles * CG_ITERS) as f64;
        let ideal = base / (c.ep_smem.cycles * CG_ITERS) as f64;
        let adapt = base
            / adapt_cycles(c.cusparse.cycles, c.ep_smem.cycles, c.ep_partition_time, CG_ITERS)
                as f64;
        t.row(&[
            c.name.clone(),
            format!("{cusp:.2}x"),
            format!("{ideal:.2}x"),
            format!("{adapt:.2}x"),
        ]);
    }
    t
}

/// Fig 11: transactions normalized to CUSPARSE.
pub fn fig11_table(cases: &[SpmvCase]) -> Table {
    let mut t = Table::new(&["name", "CUSPARSE", "CUSP", "EP"]);
    for c in cases {
        let base = c.cusparse.total_transactions() as f64;
        t.row(&[
            c.name.clone(),
            "1.00".into(),
            format!("{:.2}", c.cusp.total_transactions() as f64 / base),
            format!("{:.2}", c.ep_smem.total_transactions() as f64 / base),
        ]);
    }
    t
}

/// Fig 12: EP-smem vs EP-tex speedups over CUSPARSE.
pub fn fig12_table(cases: &[SpmvCase]) -> Table {
    let mut t = Table::new(&["name", "EP-smem", "EP-tex", "smem resident", "tex resident"]);
    for c in cases {
        let base = c.cusparse.cycles as f64;
        t.row(&[
            c.name.clone(),
            format!("{:.2}x", base / c.ep_smem.cycles as f64),
            format!("{:.2}x", base / c.ep_tex.cycles as f64),
            c.ep_smem.resident_blocks.to_string(),
            c.ep_tex.resident_blocks.to_string(),
        ]);
    }
    t
}

/// Table 3: EP-ideal cycles across thread block sizes × cache types.
pub fn table3_table(gpu: &GpuConfig, seed: u64) -> Table {
    let blocks = [256usize, 512, 1024];
    let mut t = Table::new(&[
        "name", "tex 256", "smem 256", "tex 512", "smem 512", "tex 1024", "smem 1024",
    ]);
    for (name, a) in gen::paper_suite(seed) {
        let mut cells = vec![name.to_string()];
        for &b in &blocks {
            let (smem, tex) = spmv_case_light(gpu, &a, b, seed);
            cells.push((tex.cycles * CG_ITERS).to_string());
            cells.push((smem.cycles * CG_ITERS).to_string());
        }
        t.row(&cells);
    }
    t
}

/// (smem, tex) results for one matrix at one block size (EP only).
fn spmv_case_light(gpu: &GpuConfig, a: &Coo, block: usize, seed: u64) -> (SimResult, SimResult) {
    let g = a.affinity_graph();
    let k = k_for(a.nnz(), block);
    let o = ep::EpOpts { vp: VpOpts { seed, ..Default::default() }, ..Default::default() };
    let p = ep::partition_edges(&g, k, &o);
    let b = blocked_for(a, &p, block);
    (sim_blocked_launch(gpu, &b, true, block), sim_blocked_launch(gpu, &b, false, block))
}

// -------------------------------------------------- applications (fig13-15)

pub struct AppCase {
    pub name: String,
    pub block_size: usize,
    pub original: SimResult,
    pub optimized: SimResult,
    pub partition_time: Duration,
    pub quality_default: u64,
    pub quality_ep: u64,
    pub launches: u64,
}

/// One app at one block size: original vs EP-optimized (cache per
/// Table 1), partition measured.
pub fn app_case(gpu: &GpuConfig, app: &apps::AppWorkload, block: usize, seed: u64) -> AppCase {
    let g = &app.graph;
    let k = k_for(g.m(), block);
    let use_smem = app.cache == CacheType::Software;

    let original = sim_original(gpu, g, block);
    let t0 = Instant::now();
    let sched = crate::coordinator::optimize_graph(
        g,
        &crate::coordinator::OptOptions { k, seed, ..Default::default() },
    );
    let partition_time = t0.elapsed();
    let optimized =
        sim_task_graph_launch(gpu, g, &sched.partition, Some(&sched.layout), use_smem, block);
    let quality_default =
        quality::vertex_cut_cost(g, &default_sched::default_partition(g.m(), k));
    AppCase {
        name: app.name.to_string(),
        block_size: block,
        original,
        optimized,
        partition_time,
        quality_default,
        quality_ep: sched.quality,
        launches: app.kernel_launches as u64,
    }
}

/// Fig 13: per-app, per-block-size original vs EP-adapt runtimes.
pub fn fig13_cases(gpu: &GpuConfig, seed: u64) -> Vec<AppCase> {
    let mut rows = Vec::new();
    for app in apps::rodinia_suite(seed) {
        for &b in &app.block_sizes {
            rows.push(app_case(gpu, &app, b, seed));
        }
    }
    rows
}

/// EP-ideal = per-launch kernel speedup (optimization cost amortized);
/// EP-adapt = with the *measured* partition wall time charged at the
/// modelled 1 GHz clock.  At laptop workload scale the adaptive column
/// often stays at 1.00x — the controller honouring its "no slowdown"
/// guarantee while the optimizer can't amortize — whereas the paper's
/// second-scale kernels amortize within a few launches; both columns
/// are reported for that reason (see EXPERIMENTS.md).
pub fn fig13_table(cases: &[AppCase]) -> Table {
    let mut t = Table::new(&[
        "app", "block", "original cyc", "EP-ideal cyc", "ideal", "adapt", "q default", "q EP",
    ]);
    for c in cases {
        let adapt =
            adapt_cycles(c.original.cycles, c.optimized.cycles, c.partition_time, c.launches);
        let orig_total = c.original.cycles * c.launches;
        let ideal_total = c.optimized.cycles * c.launches;
        t.row(&[
            c.name.clone(),
            c.block_size.to_string(),
            orig_total.to_string(),
            ideal_total.to_string(),
            format!("{:.2}x", orig_total as f64 / ideal_total.max(1) as f64),
            format!("{:.2}x", orig_total as f64 / adapt.max(1) as f64),
            c.quality_default.to_string(),
            c.quality_ep.to_string(),
        ]);
    }
    t
}

/// Fig 14: best EP vs best original per app (normalized runtime).
pub fn fig14_table(cases: &[AppCase]) -> Table {
    use std::collections::BTreeMap;
    let mut best: BTreeMap<&str, (u64, u64, u64)> = BTreeMap::new();
    for c in cases {
        let adapt =
            adapt_cycles(c.original.cycles, c.optimized.cycles, c.partition_time, c.launches);
        let orig_total = c.original.cycles * c.launches;
        let ideal_total = c.optimized.cycles * c.launches;
        let e =
            best.entry(c.name.as_str() as &str).or_insert((u64::MAX, u64::MAX, u64::MAX));
        e.0 = e.0.min(orig_total);
        e.1 = e.1.min(ideal_total);
        e.2 = e.2.min(adapt);
    }
    let mut t = Table::new(&[
        "app", "best original", "best EP-ideal", "best EP-adapt", "ideal norm", "adapt norm",
    ]);
    for (name, (orig, ideal, adapt)) in best {
        t.row(&[
            name.to_string(),
            orig.to_string(),
            ideal.to_string(),
            adapt.to_string(),
            format!("{:.2}", ideal as f64 / orig as f64),
            format!("{:.2}", adapt as f64 / orig as f64),
        ]);
    }
    t
}

/// Fig 15: read transactions normalized to original, per app/block.
pub fn fig15_table(cases: &[AppCase]) -> Table {
    let mut t = Table::new(&["app", "block", "original rd tx", "EP rd tx", "normalized"]);
    for c in cases {
        t.row(&[
            c.name.clone(),
            c.block_size.to_string(),
            c.original.read_transactions.to_string(),
            c.optimized.read_transactions.to_string(),
            format!(
                "{:.2}",
                c.optimized.read_transactions as f64 / c.original.read_transactions.max(1) as f64
            ),
        ]);
    }
    t
}

// ---------------------------------------------------------------- ablations

/// Ablations over the EP design choices DESIGN.md calls out.
pub fn ablation_table(seed: u64) -> Table {
    use crate::partition::vertex::Matching;
    let mut t = Table::new(&["graph", "variant", "quality", "time"]);
    for (name, mat) in [
        ("cant", gen::cant_s(2048, seed)),
        ("scircuit", gen::scircuit_s(8192, seed + 7)),
        ("mc2depi", gen::mc2depi_s(96, seed + 6)),
    ] {
        let g = mat.affinity_graph();
        let k = k_for(g.m(), BLOCK_SIZE);
        let run = |label: &str, o: ep::EpOpts, t: &mut Table| {
            let t0 = Instant::now();
            let p = ep::partition_edges(&g, k, &o);
            let dt = t0.elapsed();
            t.row(&[
                name.to_string(),
                label.to_string(),
                quality::vertex_cut_cost(&g, &p).to_string(),
                format!("{:.3}s", dt.as_secs_f64()),
            ]);
        };
        let base =
            || ep::EpOpts { vp: VpOpts { seed, ..Default::default() }, ..Default::default() };
        run("baseline (fast k-way, HEM, index chain)", base(), &mut t);
        {
            let mut o = base();
            o.fast_kway = false;
            run("recursive bisection (quality mode)", o, &mut t);
        }
        {
            let mut o = base();
            o.vp.matching = Matching::Random;
            run("random matching", o, &mut t);
        }
        {
            let mut o = base();
            o.vp.fm_passes = 0;
            run("no FM refinement", o, &mut t);
        }
        {
            let mut o = base();
            o.vp.fm_passes = 4;
            run("4 FM passes", o, &mut t);
        }
        {
            let mut o = base();
            o.chain = ep::ChainOrder::Random;
            run("random clone chain", o, &mut t);
        }
    }
    t
}

// ------------------------------------------------------------- graph builds

/// Build-cost microbench: affinity graph + transform per matrix.
pub fn partition_scaling_table(seed: u64) -> Table {
    let mut t = Table::new(&["graph", "m", "EP time", "HP time", "HP/EP"]);
    for (name, scale) in [("scircuit-1x", 4096), ("scircuit-2x", 8192), ("scircuit-4x", 16384)] {
        let a = gen::scircuit_s(scale, seed);
        let g = a.affinity_graph();
        let k = k_for(g.m(), BLOCK_SIZE);
        let t0 = Instant::now();
        let o = ep::EpOpts { vp: VpOpts { seed, ..Default::default() }, ..Default::default() };
        let _ = ep::partition_edges(&g, k, &o);
        let ept = t0.elapsed();
        let t1 = Instant::now();
        let _ = hypergraph::partition_edges(&g, k, &hypergraph::HpOpts { seed, ..Default::default() });
        let hpt = t1.elapsed();
        t.row(&[
            name.to_string(),
            g.m().to_string(),
            format!("{:.3}s", ept.as_secs_f64()),
            format!("{:.3}s", hpt.as_secs_f64()),
            format!("{:.1}x", hpt.as_secs_f64() / ept.as_secs_f64().max(1e-9)),
        ]);
    }
    t
}

/// Headline sanity: the §1 claim that ~73% of cfd's loads are redundant
/// under default scheduling.
pub fn redundancy_headline(seed: u64) -> String {
    let g = Graph::from_edges(0, vec![]);
    let _ = g;
    let app = apps::cfd(110, seed);
    let k = k_for(app.graph.m(), 256);
    let p = default_sched::default_partition(app.graph.m(), k);
    let f = stats::redundant_load_fraction(&app.graph, &p.assign, k);
    format!("cfd redundant-load fraction under default schedule: {:.1}%", f * 100.0)
}
