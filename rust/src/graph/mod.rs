//! Data-affinity graphs: structure, generators, statistics.
//!
//! `csr::Graph` is the edge-centric model's substrate (Definition 1):
//! vertices = data objects, edges = tasks.  `gen` synthesizes the
//! structural families the paper evaluates on; `stats` computes the
//! degree-distribution analyses of Fig 4/5 and the reuse go/no-go check.
//! `delta` defines the canonical edge-delta semantics dynamic-graph
//! requests are resolved through.

pub mod csr;
pub mod delta;
pub mod gen;
pub mod stats;

pub use csr::{EdgeId, Graph, VertexId};
pub use delta::EdgeDelta;
