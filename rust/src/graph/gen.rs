//! Data-affinity graph generators.
//!
//! The paper evaluates on UF-collection matrices (cant, circuit5M,
//! in-2004, mc2depi, scircuit, …) and Rodinia inputs we cannot ship.
//! These generators synthesize graphs from the same structural families
//! at laptop scale — what matters for partitioner behaviour is the
//! *degree distribution and locality structure* (paper Fig 4/5), which
//! each generator reproduces.  All generators are seeded/deterministic.

use crate::util::rng::Pcg32;

use super::csr::Graph;

/// 2D grid mesh with 4-point stencil edges — the mc2depi / cfd family:
/// nearly all vertices have degree 4 (interior), borders 2–3.
pub fn grid_mesh(rows: usize, cols: usize) -> Graph {
    let n = rows * cols;
    let at = |r: usize, c: usize| (r * cols + c) as u32;
    let mut edges = Vec::with_capacity(2 * n);
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((at(r, c), at(r, c + 1)));
            }
            if r + 1 < rows {
                edges.push((at(r, c), at(r + 1, c)));
            }
        }
    }
    Graph::from_edges(n, edges)
}

/// Banded FEM-style graph — the cant family: each vertex connects to a
/// dense clique-ish band of nearby vertices (structural-mechanics
/// stencils give degrees clustered in the 20–40 range).
pub fn fem_banded(n: usize, band: usize, fill: f64, seed: u64) -> Graph {
    let mut rng = Pcg32::new(seed);
    let mut edges = Vec::new();
    for u in 0..n {
        for d in 1..=band {
            let v = u + d;
            if v < n && rng.gen_f64() < fill {
                edges.push((u as u32, v as u32));
            }
        }
    }
    Graph::from_edges(n, edges)
}

/// Preferential-attachment (Barabási–Albert) power-law graph — the
/// in-2004 / scircuit family (web / circuit graphs with heavy tails).
pub fn power_law(n: usize, m_per_node: usize, seed: u64) -> Graph {
    assert!(n > m_per_node && m_per_node >= 1);
    let mut rng = Pcg32::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(n * m_per_node);
    // endpoint pool: each vertex appears once per incident edge, so
    // sampling uniformly from the pool = degree-proportional sampling.
    let mut pool: Vec<u32> = Vec::with_capacity(2 * n * m_per_node);
    // seed clique over the first m_per_node+1 vertices
    for u in 0..=m_per_node {
        for v in (u + 1)..=m_per_node {
            edges.push((u as u32, v as u32));
            pool.push(u as u32);
            pool.push(v as u32);
        }
    }
    for u in (m_per_node + 1)..n {
        let mut targets = Vec::with_capacity(m_per_node);
        let mut guard = 0;
        while targets.len() < m_per_node && guard < 100 * m_per_node {
            let t = pool[rng.gen_range(pool.len())];
            if t as usize != u && !targets.contains(&t) {
                targets.push(t);
            }
            guard += 1;
        }
        for &t in &targets {
            edges.push((u as u32, t));
            pool.push(u as u32);
            pool.push(t);
        }
    }
    Graph::from_edges(n, edges)
}

/// Uniform random multigraph — the circuit5M family's "more random"
/// degree spread (Erdős–Rényi G(n, m)).
pub fn random_uniform(n: usize, m: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::new(seed);
    let mut edges = Vec::with_capacity(m);
    for _ in 0..m {
        let u = rng.gen_range(n) as u32;
        let mut v = rng.gen_range(n) as u32;
        if u == v {
            v = ((v as usize + 1) % n) as u32;
        }
        edges.push((u, v));
    }
    Graph::from_edges(n, edges)
}

/// Unstructured triangular-mesh interaction graph — the cfd benchmark's
/// particle-interaction pattern (Fig 1): bounded degree ≤ `max_deg`,
/// mesh-like locality.  Built by jittered-grid triangulation.
pub fn cfd_mesh(rows: usize, cols: usize, seed: u64) -> Graph {
    let mut rng = Pcg32::new(seed);
    let n = rows * cols;
    let at = |r: usize, c: usize| (r * cols + c) as u32;
    let mut edges = Vec::new();
    for r in 0..rows {
        for c in 0..cols {
            if c + 1 < cols {
                edges.push((at(r, c), at(r, c + 1)));
            }
            if r + 1 < rows {
                edges.push((at(r, c), at(r + 1, c)));
            }
            // one diagonal per cell, orientation random — triangulation
            if c + 1 < cols && r + 1 < rows {
                if rng.gen_f64() < 0.5 {
                    edges.push((at(r, c), at(r + 1, c + 1)));
                } else {
                    edges.push((at(r, c + 1), at(r + 1, c)));
                }
            }
        }
    }
    Graph::from_edges(n, edges)
}

/// Complete graph K_n (special-pattern: clique).
pub fn clique(n: usize) -> Graph {
    let mut edges = Vec::with_capacity(n * (n - 1) / 2);
    for u in 0..n {
        for v in (u + 1)..n {
            edges.push((u as u32, v as u32));
        }
    }
    Graph::from_edges(n, edges)
}

/// Path graph P_n (special-pattern: path).
pub fn path(n: usize) -> Graph {
    Graph::from_edges(n, (0..n.saturating_sub(1)).map(|i| (i as u32, i as u32 + 1)).collect())
}

/// Complete bipartite K_{a,b} (special-pattern; streamcluster-like
/// point-to-centers sharing).
pub fn complete_bipartite(a: usize, b: usize) -> Graph {
    let mut edges = Vec::with_capacity(a * b);
    for u in 0..a {
        for v in 0..b {
            edges.push((u as u32, (a + v) as u32));
        }
    }
    Graph::from_edges(a + b, edges)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_mesh_degrees() {
        let g = grid_mesh(10, 10);
        assert_eq!(g.n, 100);
        assert_eq!(g.m(), 2 * 10 * 9);
        let h = g.degree_histogram();
        // 4 corners deg 2, 32 border deg 3, 64 interior deg 4
        assert_eq!(h[2], 4);
        assert_eq!(h[3], 32);
        assert_eq!(h[4], 64);
        g.validate().unwrap();
    }

    #[test]
    fn power_law_has_heavy_tail() {
        let g = power_law(2000, 3, 42);
        g.validate().unwrap();
        let h = g.degree_histogram();
        let dmax = g.max_degree();
        // heavy tail: the max degree must far exceed the mean
        assert!(dmax as f64 > 5.0 * g.avg_degree(), "dmax={dmax} avg={}", g.avg_degree());
        // most vertices sit at the minimum attachment degree
        let low: usize = h.iter().take(6).sum();
        assert!(low > g.n / 2);
    }

    #[test]
    fn fem_banded_degree_range() {
        let g = fem_banded(500, 30, 0.9, 7);
        g.validate().unwrap();
        assert!(g.max_degree() <= 60);
        assert!(g.avg_degree() > 20.0);
    }

    #[test]
    fn cfd_mesh_bounded_degree() {
        let g = cfd_mesh(20, 20, 3);
        g.validate().unwrap();
        assert!(g.max_degree() <= 8);
        assert!((2.0..=8.0).contains(&g.avg_degree()));
    }

    #[test]
    fn special_patterns_shapes() {
        assert_eq!(clique(6).m(), 15);
        assert_eq!(path(6).m(), 5);
        let kb = complete_bipartite(3, 4);
        assert_eq!(kb.m(), 12);
        assert_eq!(kb.degree(0), 4);
        assert_eq!(kb.degree(3), 3);
    }

    #[test]
    fn random_uniform_counts() {
        let g = random_uniform(100, 500, 9);
        assert_eq!(g.m(), 500);
        g.validate().unwrap();
    }

    #[test]
    fn generators_are_deterministic() {
        let a = power_law(300, 2, 5);
        let b = power_law(300, 2, 5);
        assert_eq!(a.edges, b.edges);
    }
}
