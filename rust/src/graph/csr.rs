//! Data-affinity graph (Definition 1 of the paper).
//!
//! Vertices are *data objects*, edges are *tasks* that touch exactly two
//! data objects.  The graph is an undirected multigraph (two tasks may
//! touch the same pair), stored as an edge list plus a CSR incidence
//! structure so partitioners can iterate a vertex's incident tasks in
//! O(degree).

/// Edge id — tasks are identified by their index in `edges`.
pub type EdgeId = u32;
/// Vertex id — data objects.
pub type VertexId = u32;

#[derive(Clone, Debug)]
pub struct Graph {
    /// Number of vertices (data objects).
    pub n: usize,
    /// Task list: `edges[e] = (u, v)`; self-loops (u == v) are allowed
    /// and model tasks whose two operands alias one object.
    pub edges: Vec<(VertexId, VertexId)>,
    /// CSR offsets into `inc`, length n + 1.
    inc_ptr: Vec<u32>,
    /// Incidence: for each vertex, (edge id, other endpoint) pairs.
    inc: Vec<(EdgeId, VertexId)>,
}

impl Graph {
    /// Build from an edge list. Panics if an endpoint is out of range.
    pub fn from_edges(n: usize, edges: Vec<(VertexId, VertexId)>) -> Self {
        let mut deg = vec![0u32; n];
        for &(u, v) in &edges {
            assert!((u as usize) < n && (v as usize) < n, "endpoint out of range");
            deg[u as usize] += 1;
            if u != v {
                deg[v as usize] += 1;
            }
        }
        let mut inc_ptr = vec![0u32; n + 1];
        for i in 0..n {
            inc_ptr[i + 1] = inc_ptr[i] + deg[i];
        }
        let mut cursor = inc_ptr[..n].to_vec();
        let mut inc = vec![(0u32, 0u32); inc_ptr[n] as usize];
        for (e, &(u, v)) in edges.iter().enumerate() {
            let e = e as EdgeId;
            inc[cursor[u as usize] as usize] = (e, v);
            cursor[u as usize] += 1;
            if u != v {
                inc[cursor[v as usize] as usize] = (e, u);
                cursor[v as usize] += 1;
            }
        }
        Graph { n, edges, inc_ptr, inc }
    }

    /// Number of tasks (edges).
    #[inline]
    pub fn m(&self) -> usize {
        self.edges.len()
    }

    /// Degree of vertex v = number of incident tasks (self-loops count once).
    #[inline]
    pub fn degree(&self, v: VertexId) -> usize {
        (self.inc_ptr[v as usize + 1] - self.inc_ptr[v as usize]) as usize
    }

    /// Incident (edge id, other endpoint) pairs of v.
    #[inline]
    pub fn incident(&self, v: VertexId) -> &[(EdgeId, VertexId)] {
        &self.inc[self.inc_ptr[v as usize] as usize..self.inc_ptr[v as usize + 1] as usize]
    }

    /// Maximum vertex degree (d_max in the approximation bound).
    pub fn max_degree(&self) -> usize {
        (0..self.n as u32).map(|v| self.degree(v)).max().unwrap_or(0)
    }

    /// Mean degree = 2m/n — the paper's "average data reuse" measure.
    pub fn avg_degree(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        self.inc.len() as f64 / self.n as f64
    }

    /// Histogram of vertex degrees: `hist[d]` = #vertices of degree d.
    /// This regenerates the paper's Fig 4 / Fig 5 series.
    pub fn degree_histogram(&self) -> Vec<usize> {
        let mut hist = vec![0usize; self.max_degree() + 1];
        for v in 0..self.n as u32 {
            hist[self.degree(v)] += 1;
        }
        hist
    }

    /// Sanity check of internal invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.inc_ptr.len() != self.n + 1 {
            return Err("inc_ptr length".into());
        }
        let loops = self.edges.iter().filter(|(u, v)| u == v).count();
        if self.inc.len() != 2 * self.m() - loops {
            return Err(format!(
                "incidence size {} != 2m-loops {}",
                self.inc.len(),
                2 * self.m() - loops
            ));
        }
        for v in 0..self.n as u32 {
            for &(e, o) in self.incident(v) {
                let (a, b) = self.edges[e as usize];
                let ok = (a == v && b == o) || (b == v && a == o);
                if !ok {
                    return Err(format!("incidence mismatch at v={v} e={e}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn triangle() -> Graph {
        Graph::from_edges(3, vec![(0, 1), (1, 2), (2, 0)])
    }

    #[test]
    fn triangle_structure() {
        let g = triangle();
        assert_eq!(g.m(), 3);
        assert_eq!(g.max_degree(), 2);
        assert_eq!(g.avg_degree(), 2.0);
        for v in 0..3 {
            assert_eq!(g.degree(v), 2);
        }
        g.validate().unwrap();
    }

    #[test]
    fn incident_edges_are_correct() {
        let g = triangle();
        let inc0: Vec<u32> = g.incident(0).iter().map(|&(e, _)| e).collect();
        assert_eq!(inc0, vec![0, 2]); // edges (0,1) and (2,0)
    }

    #[test]
    fn multigraph_and_self_loops() {
        let g = Graph::from_edges(2, vec![(0, 1), (0, 1), (1, 1)]);
        assert_eq!(g.degree(0), 2);
        assert_eq!(g.degree(1), 3); // two parallel + one self-loop
        g.validate().unwrap();
    }

    #[test]
    fn degree_histogram_counts() {
        // star: center degree 3, leaves degree 1
        let g = Graph::from_edges(4, vec![(0, 1), (0, 2), (0, 3)]);
        let h = g.degree_histogram();
        assert_eq!(h, vec![0, 3, 0, 1]);
    }

    #[test]
    fn empty_and_isolated() {
        let g = Graph::from_edges(3, vec![]);
        assert_eq!(g.m(), 0);
        assert_eq!(g.max_degree(), 0);
        assert_eq!(g.degree_histogram(), vec![3]);
        g.validate().unwrap();
    }

    #[test]
    #[should_panic(expected = "endpoint out of range")]
    fn rejects_out_of_range() {
        Graph::from_edges(2, vec![(0, 2)]);
    }
}
