//! Degree-distribution statistics (paper Fig 4 & Fig 5) and the reuse
//! check the optimization pipeline performs before partitioning
//! (Section 4.1: "check if there is enough data reuse by checking the
//! degree frequency of the data-affinity graph").

use super::csr::Graph;

/// One (degree, frequency) series point, frequency as a fraction of n.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DegreePoint {
    pub degree: usize,
    pub count: usize,
    pub fraction: f64,
}

/// Full degree-frequency series (Fig 4), skipping empty degrees.
pub fn degree_distribution(g: &Graph) -> Vec<DegreePoint> {
    let hist = g.degree_histogram();
    let n = g.n.max(1) as f64;
    hist.iter()
        .enumerate()
        .filter(|&(_, &c)| c > 0)
        .map(|(degree, &count)| DegreePoint { degree, count, fraction: count as f64 / n })
        .collect()
}

/// Log-log regression slope of the degree distribution tail (Fig 5):
/// power-law graphs show a clear negative slope; mesh-like graphs don't
/// have enough distinct degrees to fit (returns None).
pub fn log_log_slope(g: &Graph) -> Option<f64> {
    let pts: Vec<(f64, f64)> = degree_distribution(g)
        .into_iter()
        .filter(|p| p.degree >= 1 && p.count >= 1)
        .map(|p| ((p.degree as f64).ln(), (p.count as f64).ln()))
        .collect();
    if pts.len() < 4 {
        return None;
    }
    let n = pts.len() as f64;
    let sx: f64 = pts.iter().map(|p| p.0).sum();
    let sy: f64 = pts.iter().map(|p| p.1).sum();
    let sxx: f64 = pts.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = pts.iter().map(|p| p.0 * p.1).sum();
    let denom = n * sxx - sx * sx;
    if denom.abs() < 1e-12 {
        return None;
    }
    Some((n * sxy - sx * sy) / denom)
}

/// The pipeline's go/no-go reuse check: average degree ≈ average number
/// of tasks sharing a data object.  The paper notes streamcluster's
/// average degree ≤ 2 yields little benefit; we use that as the default
/// threshold.
pub fn has_enough_reuse(g: &Graph, threshold: f64) -> bool {
    g.avg_degree() > threshold
}

/// Paper §1: fraction of loads that are redundant under a given schedule
/// upper bound — with perfect intra-block sharing, every appearance of a
/// vertex beyond its first in a block is redundant. For the *default*
/// contiguous schedule this reproduces the paper's "73.4% of particle
/// loads are redundant" style headline for cfd.
pub fn redundant_load_fraction(g: &Graph, assign: &[u32], k: usize) -> f64 {
    use std::collections::HashSet;
    let mut per_block: Vec<HashSet<u32>> = vec![HashSet::new(); k];
    let mut total_loads = 0usize;
    let mut unique_loads = 0usize;
    for (e, &(u, v)) in g.edges.iter().enumerate() {
        let b = assign[e] as usize;
        for w in [u, v] {
            total_loads += 1;
            if per_block[b].insert(w) {
                unique_loads += 1;
            }
        }
    }
    if total_loads == 0 {
        return 0.0;
    }
    (total_loads - unique_loads) as f64 / total_loads as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::gen;

    #[test]
    fn distribution_sums_to_n() {
        let g = gen::grid_mesh(8, 8);
        let total: usize = degree_distribution(&g).iter().map(|p| p.count).sum();
        assert_eq!(total, g.n);
    }

    #[test]
    fn power_law_slope_is_negative() {
        let g = gen::power_law(3000, 3, 1);
        let s = log_log_slope(&g).expect("enough distinct degrees");
        assert!(s < -0.8, "slope {s} not power-law-ish");
    }

    #[test]
    fn mesh_has_no_meaningful_slope() {
        let g = gen::grid_mesh(30, 30);
        // only 3 distinct degrees → None
        assert!(log_log_slope(&g).is_none());
    }

    #[test]
    fn reuse_check_matches_paper_examples() {
        // streamcluster-like: each thread pairs a unique point with the
        // current candidate center → star-shaped, avg degree ≤ 2
        let sc = gen::complete_bipartite(2000, 1);
        assert!(sc.avg_degree() < 2.1);
        assert!(!has_enough_reuse(&sc, 2.1));
        // cfd-like mesh: plenty of reuse
        let cfd = gen::cfd_mesh(30, 30, 2);
        assert!(has_enough_reuse(&cfd, 2.1));
    }

    #[test]
    fn redundant_fraction_bounds() {
        let g = gen::cfd_mesh(20, 20, 5);
        let k = 8;
        let chunk = g.m().div_ceil(k);
        let assign: Vec<u32> = (0..g.m()).map(|e| (e / chunk) as u32).collect();
        let f = redundant_load_fraction(&g, &assign, k);
        assert!((0.0..1.0).contains(&f));
        // a mesh under contiguous scheduling has substantial redundancy
        assert!(f > 0.3, "fraction {f}");
    }

    #[test]
    fn redundant_fraction_zero_for_disjoint() {
        // two disjoint edges in separate blocks: no redundancy
        let g = crate::graph::csr::Graph::from_edges(4, vec![(0, 1), (2, 3)]);
        let f = redundant_load_fraction(&g, &[0, 1], 2);
        assert_eq!(f, 0.0);
    }
}
