//! Edge deltas: the canonical mutation semantics for dynamic graphs.
//!
//! The serving layer's `{"base": <fingerprint>, "delta": {...}}` request
//! (PR 9) resolves a cached base graph and applies an edge delta to it;
//! the resulting graph is fingerprinted and cached like any inline
//! request.  For that sharing to be bit-exact — a delta-derived cache
//! entry and the equivalent inline full-graph request MUST collide on
//! one fingerprint — the delta application itself has to be canonical.
//! This module is that single definition; every layer (server, client,
//! tests, benches) applies deltas through it.
//!
//! ## Semantics
//!
//! * The vertex set is fixed: `n` never changes, and every endpoint in
//!   the delta must be `< n`.  (Data objects are the address space; a
//!   workload that grows it is a new base, not a delta.)
//! * `remove_edges` go first.  Each `(u, v)` pair deletes exactly one
//!   edge of the base: the lowest-id not-yet-removed edge stored as
//!   `(u, v)`, else the lowest-id not-yet-removed edge stored as
//!   `(v, u)`.  Orientation-exact-first makes removal deterministic on
//!   multigraphs; a pair that matches nothing is an error (the caller's
//!   view of the base has diverged — failing loudly beats silently
//!   serving a schedule for a different graph).
//! * Surviving edges are compacted, KEEPING their relative edge-id
//!   order — edge ids are schedule slots, so order is semantic
//!   (`service::fingerprint` hashes it).
//! * `add_edges` are appended after the survivors, in request order.
//!
//! The returned `new_of_old` map (old edge id → new edge id, or
//! [`REMOVED`] for deleted edges) is what lets the incremental
//! re-partitioner (`partition::incremental`) carry the cached block
//! assignment over to the surviving tasks.

use super::csr::Graph;

/// `new_of_old[e] == REMOVED` marks a base edge deleted by the delta.
pub const REMOVED: u32 = u32::MAX;

/// An edge delta: additions and removals over a base graph's fixed
/// vertex set.  Plain data — built by the protocol layer, the CLI, and
/// tests alike.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EdgeDelta {
    pub add_edges: Vec<(u32, u32)>,
    pub remove_edges: Vec<(u32, u32)>,
}

impl EdgeDelta {
    pub fn is_empty(&self) -> bool {
        self.add_edges.is_empty() && self.remove_edges.is_empty()
    }

    /// Total number of edge mutations — the serving layer bounds this.
    pub fn len(&self) -> usize {
        self.add_edges.len() + self.remove_edges.len()
    }
}

/// Apply `delta` to `base` under the module-doc semantics.  Returns the
/// post-delta graph plus the `new_of_old` edge-id map.  Errors (with a
/// human-readable reason) on an endpoint out of range or a removal that
/// matches no remaining edge; an error leaves no partial product.
pub fn apply_delta(base: &Graph, delta: &EdgeDelta) -> Result<(Graph, Vec<u32>), String> {
    let n = base.n as u32;
    for &(u, v) in delta.add_edges.iter().chain(&delta.remove_edges) {
        if u >= n || v >= n {
            return Err(format!("delta endpoint ({u}, {v}) out of range for n={n}"));
        }
    }
    let mut removed = vec![false; base.m()];
    for &(u, v) in &delta.remove_edges {
        // lowest-id live edge stored exactly (u, v); else stored (v, u).
        // incident(u) covers both orientations (it lists every edge
        // touching u), so one O(deg u) scan finds both candidates.
        let mut exact = REMOVED;
        let mut swapped = REMOVED;
        for &(e, other) in base.incident(u) {
            if other != v || removed[e as usize] {
                continue;
            }
            if base.edges[e as usize] == (u, v) {
                if e < exact {
                    exact = e;
                }
            } else if e < swapped {
                swapped = e;
            }
        }
        let hit = if exact != REMOVED { exact } else { swapped };
        if hit == REMOVED {
            return Err(format!("remove ({u}, {v}) matches no remaining edge"));
        }
        removed[hit as usize] = true;
    }
    let survivors = base.m() - delta.remove_edges.len();
    let mut edges = Vec::with_capacity(survivors + delta.add_edges.len());
    let mut new_of_old = vec![REMOVED; base.m()];
    for (e, &pair) in base.edges.iter().enumerate() {
        if !removed[e] {
            new_of_old[e] = edges.len() as u32;
            edges.push(pair);
        }
    }
    edges.extend_from_slice(&delta.add_edges);
    Ok((Graph::from_edges(base.n, edges), new_of_old))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> Graph {
        // multigraph with a duplicate pair and a self-loop
        Graph::from_edges(5, vec![(0, 1), (1, 2), (2, 1), (1, 2), (3, 3), (3, 4)])
    }

    #[test]
    fn add_appends_in_request_order_and_survivors_keep_order() {
        let g = base();
        let d = EdgeDelta { add_edges: vec![(4, 0), (0, 2)], remove_edges: vec![] };
        let (post, map) = apply_delta(&g, &d).unwrap();
        assert_eq!(post.n, g.n);
        assert_eq!(&post.edges[..g.m()], &g.edges[..]);
        assert_eq!(&post.edges[g.m()..], &[(4, 0), (0, 2)]);
        assert_eq!(map, (0..g.m() as u32).collect::<Vec<_>>());
        post.validate().unwrap();
    }

    #[test]
    fn remove_prefers_exact_orientation_then_lowest_id() {
        let g = base();
        // (1, 2) must take edge 1 (stored exactly), not edge 2 (stored
        // (2, 1)) even though both touch the pair
        let d = EdgeDelta { add_edges: vec![], remove_edges: vec![(1, 2)] };
        let (post, map) = apply_delta(&g, &d).unwrap();
        assert_eq!(map[1], REMOVED);
        assert_eq!(post.edges, vec![(0, 1), (2, 1), (1, 2), (3, 3), (3, 4)]);
        // swapped orientation falls back to the stored-(1,2) duplicates
        // in id order: first (2,1) request eats edge 2
        let d = EdgeDelta { add_edges: vec![], remove_edges: vec![(2, 1), (2, 1)] };
        let (post, map) = apply_delta(&g, &d).unwrap();
        assert_eq!((map[1], map[2]), (REMOVED, REMOVED));
        assert_eq!(post.edges, vec![(0, 1), (1, 2), (3, 3), (3, 4)]);
    }

    #[test]
    fn removing_duplicates_one_at_a_time() {
        let g = base();
        // three parallel (1,2)-ish edges: 1, 2, 3; three removals drain
        // them all, a fourth errors
        let d = EdgeDelta {
            add_edges: vec![],
            remove_edges: vec![(1, 2), (1, 2), (1, 2)],
        };
        let (post, _) = apply_delta(&g, &d).unwrap();
        assert_eq!(post.edges, vec![(0, 1), (3, 3), (3, 4)]);
        let d = EdgeDelta {
            add_edges: vec![],
            remove_edges: vec![(1, 2), (1, 2), (1, 2), (1, 2)],
        };
        assert!(apply_delta(&g, &d).is_err());
    }

    #[test]
    fn self_loop_removal_and_emptied_adjacency() {
        let g = base();
        // empty vertex 3's adjacency entirely
        let d = EdgeDelta { add_edges: vec![], remove_edges: vec![(3, 3), (3, 4)] };
        let (post, map) = apply_delta(&g, &d).unwrap();
        assert_eq!(post.incident(3), &[]);
        assert_eq!((map[4], map[5]), (REMOVED, REMOVED));
        assert_eq!(post.m(), 4);
        post.validate().unwrap();
    }

    #[test]
    fn out_of_range_and_unmatched_are_errors() {
        let g = base();
        let d = EdgeDelta { add_edges: vec![(0, 5)], remove_edges: vec![] };
        assert!(apply_delta(&g, &d).unwrap_err().contains("out of range"));
        let d = EdgeDelta { add_edges: vec![], remove_edges: vec![(0, 4)] };
        assert!(apply_delta(&g, &d).unwrap_err().contains("matches no remaining edge"));
    }

    #[test]
    fn delta_equals_inline_construction() {
        // the sharing contract: apply_delta's product is bit-identical
        // (n, edges, order) to building the post graph inline
        let g = base();
        let d = EdgeDelta { add_edges: vec![(0, 4)], remove_edges: vec![(1, 2), (3, 3)] };
        let (post, _) = apply_delta(&g, &d).unwrap();
        let inline = Graph::from_edges(5, vec![(0, 1), (2, 1), (1, 2), (3, 4), (0, 4)]);
        assert_eq!(post.n, inline.n);
        assert_eq!(post.edges, inline.edges);
    }

    #[test]
    fn empty_delta_is_identity() {
        let g = base();
        let (post, map) = apply_delta(&g, &EdgeDelta::default()).unwrap();
        assert_eq!(post.edges, g.edges);
        assert_eq!(map, (0..g.m() as u32).collect::<Vec<_>>());
    }
}
