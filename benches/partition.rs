//! Partitioner benchmarks — the perf-rewrite headline (optimized vs
//! retained seed pipeline on a ≥1M-edge graph at k=64) and the k-way
//! refinement headline (gain-bucket `kway_refine` vs the seed full-scan
//! refinement on the same input), both recorded in BENCH_partition.json
//! — the baseline the CI regression gate (`epgraph bench-compare`)
//! checks ratio metrics against.  Plus Fig 6 (method comparison), the
//! partition-time scaling claim ("orders of magnitude faster than
//! hypergraph"), and the DESIGN.md ablations.
//!
//!     cargo bench --offline --bench partition
//!
//! Set EPGRAPH_BENCH_SMOKE=1 for a fast CI-sized run (the JSON baseline
//! records the mode, so full and smoke baselines are never confused).
//!
//! criterion is unavailable offline; this uses the in-repo harness
//! (epgraph::util::benchkit) with warmup + multi-iteration stats.

use epgraph::coordinator::{optimize_delta, optimize_graph, OptOptions};
use epgraph::graph::delta::{apply_delta, EdgeDelta};
use epgraph::graph::gen as ggen;
use epgraph::experiments as exp;
use epgraph::partition::vertex::{self, Mode, VpOpts};
use epgraph::partition::{ep, hypergraph, quality, reference, Method};
use epgraph::sparse::gen;
use epgraph::util::benchkit::{bench, time_once, JsonReport};

/// Best-of-`reps` wall clock (min is the standard noise-robust pick) —
/// the smoke-mode ratios feed the CI regression gate, where a single
/// sample on a shared runner would make the 25% tolerance flaky.
fn timed_min<T, F: FnMut() -> T>(reps: usize, mut f: F) -> (T, std::time::Duration) {
    let (mut out, mut best) = time_once(&mut f);
    for _ in 1..reps.max(1) {
        let (o, t) = time_once(&mut f);
        if t < best {
            best = t;
            out = o;
        }
    }
    (out, best)
}

/// Repetitions for the headline timings: smoke runs are cheap (and
/// gated), full runs are minutes-long single shots.
fn headline_reps(smoke: bool) -> usize {
    if smoke {
        3
    } else {
        1
    }
}

/// Headline: the rewrite's speedup over the retained seed pipeline on a
/// power-law task graph, single-threaded (algorithmic gain alone) and
/// multi-threaded (scaling on top), with cut-quality parity recorded.
fn perf_headline(seed: u64, r: &mut JsonReport) {
    let smoke = std::env::var("EPGRAPH_BENCH_SMOKE").is_ok();
    // power_law(n, 3) has m ~= 3n tasks; full mode crosses 1M edges
    let n = if smoke { 60_000 } else { 350_000 };
    let k = 64;
    println!("## perf-rewrite headline ({})\n", if smoke { "smoke" } else { "full" });
    let g = ggen::power_law(n, 3, seed);
    println!("power_law({n}, 3): n={} m={} k={k}", g.n, g.m());

    let opts_1t = ep::EpOpts {
        vp: VpOpts { seed, threads: 1, ..Default::default() },
        ..Default::default()
    };
    let opts_mt = {
        let mut o = opts_1t.clone();
        o.vp.threads = 0; // one per core
        o
    };

    let reps = headline_reps(smoke);
    let (p_ref, t_ref) = timed_min(reps, || reference::partition_edges_naive(&g, k, &opts_1t));
    let (p_1t, t_1t) = timed_min(reps, || ep::partition_edges(&g, k, &opts_1t));
    let (p_mt, t_mt) = timed_min(reps, || ep::partition_edges(&g, k, &opts_mt));

    // cut accounting on the parallel deterministic reduction (PERF.md)
    let cut_ref = quality::vertex_cut_cost_par(&g, &p_ref, 0);
    let cut_new = quality::vertex_cut_cost_par(&g, &p_1t, 0);
    let cut_mt = quality::vertex_cut_cost_par(&g, &p_mt, 0);
    assert_eq!(p_1t.assign, p_mt.assign, "thread count must not change the partition");

    let s1 = t_ref.as_secs_f64() / t_1t.as_secs_f64().max(1e-9);
    let smt = t_ref.as_secs_f64() / t_mt.as_secs_f64().max(1e-9);
    println!("  seed pipeline (reference): {:>10.3}s  cut={cut_ref}", t_ref.as_secs_f64());
    println!("  rewrite, 1 thread:         {:>10.3}s  cut={cut_new}  speedup={s1:.2}x", t_1t.as_secs_f64());
    println!("  rewrite, all cores:        {:>10.3}s  cut={cut_mt}  speedup={smt:.2}x", t_mt.as_secs_f64());

    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    r.str("bench", "partition")
        .str("mode", if smoke { "smoke" } else { "full" })
        .raw(
            "graph",
            &format!("{{\"generator\": \"power_law\", \"n\": {}, \"m\": {}}}", g.n, g.m()),
        )
        .int("k", k as u64)
        .int("seed", seed)
        .int("cores", cores as u64)
        .num("ref_secs", t_ref.as_secs_f64())
        .num("new_1t_secs", t_1t.as_secs_f64())
        .num("new_mt_secs", t_mt.as_secs_f64())
        .num("speedup_single_thread", s1)
        .num("speedup_multi_thread", smt)
        .int("ref_cut", cut_ref)
        .int("new_cut", cut_new)
        .num("cut_ratio_new_over_ref", cut_new as f64 / cut_ref.max(1) as f64);
}

/// k = 64 refinement-heavy headline: the k-way gain-bucket rewrite
/// (`vertex::kway_refine`) vs the retained seed full-scan refinement
/// (`reference::kway_refine`) on the SAME task graph from the SAME
/// deliberately-unrefined starting partition (contiguous task slabs —
/// plenty of boundary, so refinement dominates the wall clock).
fn kway_refine_headline(seed: u64, r: &mut JsonReport) {
    let smoke = std::env::var("EPGRAPH_BENCH_SMOKE").is_ok();
    // tasks m ≈ 3n: full mode crosses 1M tasks in the refined graph
    let n = if smoke { 60_000 } else { 350_000 };
    let k = 64usize;
    println!("## k-way refinement headline ({}, k={k})\n", if smoke { "smoke" } else { "full" });
    let g = ggen::power_law(n, 3, seed ^ 0x6B77);
    let tg = ep::task_graph(&g, ep::ChainOrder::Index, seed);
    println!("task graph: n={} (tasks) k={k}", tg.n);

    // contiguous slabs: balanced by construction, maximal boundary
    let part0: Vec<u32> = (0..tg.n).map(|v| (v * k / tg.n) as u32).collect();
    let cut0 = tg.edge_cut_par(&part0, 0);

    let vp_1t = VpOpts { seed, threads: 1, ..Default::default() };
    let vp_mt = VpOpts { seed, threads: 0, ..Default::default() };

    let reps = headline_reps(smoke);
    let (p_ref, t_ref) = timed_min(reps, || {
        let mut p = part0.clone();
        reference::kway_refine(&tg, &mut p, k, &vp_1t);
        p
    });
    let (p_1t, t_1t) = timed_min(reps, || {
        let mut p = part0.clone();
        vertex::kway_refine(&tg, &mut p, k, &vp_1t);
        p
    });
    let (p_mt, t_mt) = timed_min(reps, || {
        let mut p = part0.clone();
        vertex::kway_refine(&tg, &mut p, k, &vp_mt);
        p
    });
    assert_eq!(p_1t, p_mt, "thread count must not change kway_refine");

    let cut_ref = tg.edge_cut_par(&p_ref, 0);
    let cut_new = tg.edge_cut_par(&p_1t, 0);
    let s1 = t_ref.as_secs_f64() / t_1t.as_secs_f64().max(1e-9);
    let smt = t_ref.as_secs_f64() / t_mt.as_secs_f64().max(1e-9);
    println!("  start cut: {cut0}");
    println!("  seed full-scan refine:   {:>10.3}s  cut={cut_ref}", t_ref.as_secs_f64());
    println!("  gain buckets, 1 thread:  {:>10.3}s  cut={cut_new}  speedup={s1:.2}x", t_1t.as_secs_f64());
    println!("  gain buckets, all cores: {:>10.3}s  speedup={smt:.2}x", t_mt.as_secs_f64());

    r.int("kway_tasks", tg.n as u64)
        .int("kway_start_cut", cut0 as u64)
        .num("kway_refine_ref_secs", t_ref.as_secs_f64())
        .num("kway_refine_new_secs", t_1t.as_secs_f64())
        .num("kway_refine_new_mt_secs", t_mt.as_secs_f64())
        .num("kway_refine_speedup", s1)
        .num("kway_refine_mt_speedup", smt)
        .int("kway_ref_cut", cut_ref as u64)
        .int("kway_new_cut", cut_new as u64)
        .num("kway_cut_ratio_new_over_ref", cut_new as f64 / (cut_ref.max(1)) as f64);
}

/// PR 9 headline: incremental re-partitioning of a dynamic graph.  A
/// deterministic ≤1% edge delta (every 200th edge out, the same count
/// of fresh edges in) against an already-optimized power-law base;
/// `optimize_delta` warm-starts from the base's partition and must land
/// within 5% of a cold full re-optimization's cut (hard in-bench
/// assert) at a fraction of its wall clock (`delta_refine_speedup`,
/// benchkit-gated against the committed floor).
fn delta_headline(seed: u64, r: &mut JsonReport) {
    let smoke = std::env::var("EPGRAPH_BENCH_SMOKE").is_ok();
    // power_law(n, 3): m ≈ 3n, so even smoke mode clears 100k edges
    let n = if smoke { 60_000 } else { 350_000 };
    let k = 64usize;
    println!("\n## incremental re-partition headline ({}, k={k})\n", if smoke { "smoke" } else { "full" });
    let g = ggen::power_law(n, 3, seed ^ 0xD317);
    let nn = g.n as u64;
    let step = 200; // 1/200 removed + 1/200 added = 1% of m mutated
    let delta = EdgeDelta {
        remove_edges: (0..g.m() / step).map(|i| g.edges[i * step]).collect(),
        add_edges: (0..g.m() / step)
            .map(|i| {
                let u = ((i as u64 * 7919 + 13) % nn) as u32;
                let v = ((i as u64 * 104_729 + 71) % nn) as u32;
                if u == v {
                    (u, (v + 1) % nn as u32)
                } else {
                    (u, v)
                }
            })
            .collect(),
    };
    println!(
        "power_law({n}, 3): n={} m={}, delta {} mutations ({:.2}% of m)",
        g.n,
        g.m(),
        delta.len(),
        delta.len() as f64 / g.m() as f64 * 100.0
    );

    let opts = OptOptions { k, seed, threads: 1, ..Default::default() };
    let base = optimize_graph(&g, &opts);
    let (post, new_of_old) = apply_delta(&g, &delta).expect("delta applies to the base");

    let reps = headline_reps(smoke);
    let (full, t_full) = timed_min(reps, || optimize_graph(&post, &opts));
    let (inc, t_inc) = timed_min(reps, || optimize_delta(&base, &post, &new_of_old, &opts).0);
    // determinism across thread counts — the serving layer's
    // bit-identical-schedule contract rests on this
    let mt = OptOptions { threads: 0, ..opts.clone() };
    let (inc_mt, _) = optimize_delta(&base, &post, &new_of_old, &mt);
    assert_eq!(
        inc.partition.assign, inc_mt.partition.assign,
        "thread count must not change the refined partition"
    );

    let speedup = t_full.as_secs_f64() / t_inc.as_secs_f64().max(1e-9);
    let ratio = inc.quality as f64 / full.quality.max(1) as f64;
    println!("  full re-optimize:    {:>10.3}s  cut={}", t_full.as_secs_f64(), full.quality);
    println!(
        "  delta refine:        {:>10.3}s  cut={}  speedup={speedup:.2}x  cut_ratio={ratio:.4}",
        t_inc.as_secs_f64(),
        inc.quality
    );
    assert!(
        ratio <= 1.05,
        "delta cut {} exceeds full re-optimization cut {} by more than 5%",
        inc.quality,
        full.quality
    );

    r.int("delta_mutations", delta.len() as u64)
        .num("delta_pct_of_m", delta.len() as f64 / g.m() as f64 * 100.0)
        .num("delta_full_secs", t_full.as_secs_f64())
        .num("delta_refine_secs", t_inc.as_secs_f64())
        .num("delta_refine_speedup", speedup)
        .int("delta_full_cut", full.quality)
        .int("delta_cut", inc.quality)
        .num("delta_cut_ratio", ratio);
}

/// PR 10 headline: the data-parallel engines (`Mode::Lp` —
/// label-propagation coarsening + conflict-free parallel boundary
/// refinement) vs the FM quality reference on the same cold k=64
/// partition, both on all cores.  FM stays the serving default; LP buys
/// miss latency (`lp_speedup`, benchkit-gated against the committed
/// floor) at a bounded quality cost (`lp_cut_ratio` ≤ 1.15, hard
/// in-bench assert AND a lower-is-better gate).
fn lp_headline(seed: u64, r: &mut JsonReport) {
    let smoke = std::env::var("EPGRAPH_BENCH_SMOKE").is_ok();
    // power_law(n, 3): m ≈ 3n, so full mode crosses 1M edges
    let n = if smoke { 60_000 } else { 350_000 };
    let k = 64usize;
    println!("\n## data-parallel LP headline ({}, k={k})\n", if smoke { "smoke" } else { "full" });
    let g = ggen::power_law(n, 3, seed ^ 0x1B9A);
    println!("power_law({n}, 3): n={} m={} k={k}", g.n, g.m());

    let fm = ep::EpOpts {
        vp: VpOpts { seed, threads: 0, ..Default::default() },
        ..Default::default()
    };
    let lp = {
        let mut o = fm.clone();
        o.vp.mode = Mode::Lp;
        o
    };

    let reps = headline_reps(smoke);
    let (p_fm, t_fm) = timed_min(reps, || ep::partition_edges(&g, k, &fm));
    let (p_lp, t_lp) = timed_min(reps, || ep::partition_edges(&g, k, &lp));
    // the serving contract extends to LP: one cache entry per
    // fingerprint regardless of the worker pool size
    let lp_1t = {
        let mut o = lp.clone();
        o.vp.threads = 1;
        o
    };
    let p_lp_1t = ep::partition_edges(&g, k, &lp_1t);
    assert_eq!(p_lp.assign, p_lp_1t.assign, "thread count must not change the LP partition");

    let cut_fm = quality::vertex_cut_cost_par(&g, &p_fm, 0);
    let cut_lp = quality::vertex_cut_cost_par(&g, &p_lp, 0);
    let speedup = t_fm.as_secs_f64() / t_lp.as_secs_f64().max(1e-9);
    let ratio = cut_lp as f64 / cut_fm.max(1) as f64;
    println!("  fm (quality reference): {:>10.3}s  cut={cut_fm}", t_fm.as_secs_f64());
    println!(
        "  lp (data-parallel):     {:>10.3}s  cut={cut_lp}  speedup={speedup:.2}x  cut_ratio={ratio:.4}",
        t_lp.as_secs_f64()
    );
    assert!(
        ratio <= 1.15,
        "LP cut {cut_lp} exceeds the FM reference cut {cut_fm} by more than 15%"
    );

    r.num("lp_fm_secs", t_fm.as_secs_f64())
        .num("lp_secs", t_lp.as_secs_f64())
        .num("lp_speedup", speedup)
        .int("lp_fm_cut", cut_fm)
        .int("lp_cut", cut_lp)
        .num("lp_cut_ratio", ratio);
}

fn main() {
    let seed = 42;

    let mut report = JsonReport::new();
    perf_headline(seed, &mut report);
    kway_refine_headline(seed, &mut report);
    delta_headline(seed, &mut report);
    lp_headline(seed, &mut report);
    match report.write("BENCH_partition.json") {
        Ok(()) => println!("\n  baseline written to BENCH_partition.json\n"),
        Err(e) => println!("\n  WARNING: could not write BENCH_partition.json: {e}\n"),
    }

    println!("## partitioner micro-benchmarks (per-call latency)\n");
    for (name, a) in [
        ("mc2depi_s(96)", gen::mc2depi_s(96, seed)),
        ("scircuit_s(8192)", gen::scircuit_s(8192, seed + 7)),
        ("cant_s(2048)", gen::cant_s(2048, seed)),
    ] {
        let g = a.affinity_graph();
        let k = g.m().div_ceil(exp::BLOCK_SIZE).max(2);
        println!("{name}: n={} m={} k={k}", g.n, g.m());

        let s = bench("  ep::task_graph (transform)", 1, 10, || {
            ep::task_graph(&g, ep::ChainOrder::Index, seed)
        });
        println!("{}", s.row());

        let s = bench("  ep::partition_edges (full EP)", 1, 5, || {
            let o = ep::EpOpts {
                vp: VpOpts { seed, ..Default::default() },
                ..Default::default()
            };
            ep::partition_edges(&g, k, &o)
        });
        println!("{}", s.row());

        let s = bench("  powergraph greedy", 1, 5, || {
            Method::PgGreedy.partition(&g, k, seed)
        });
        println!("{}", s.row());

        let s = bench("  hypergraph (baseline)", 0, 2, || {
            hypergraph::partition_edges(
                &g,
                k,
                &hypergraph::HpOpts { seed, ..Default::default() },
            )
        });
        println!("{}", s.row());
        println!();
    }

    println!("## Fig 6: partition model comparison (quality + one-shot time)\n");
    exp::fig6_table(&exp::fig6_partition(seed)).print();

    println!("\n## partition-time scaling (EP vs HP as graphs grow)\n");
    exp::partition_scaling_table(seed).print();

    println!("\n## ablations (DESIGN.md §6)\n");
    exp::ablation_table(seed).print();
}
