//! Partitioner benchmarks — regenerates Fig 6 (method comparison),
//! the partition-time scaling claim ("orders of magnitude faster than
//! hypergraph"), and the DESIGN.md ablations.
//!
//!     cargo bench --offline --bench partition
//!
//! criterion is unavailable offline; this uses the in-repo harness
//! (epgraph::util::benchkit) with warmup + multi-iteration stats.

use epgraph::experiments as exp;
use epgraph::partition::{ep, hypergraph, Method};
use epgraph::sparse::gen;
use epgraph::util::benchkit::bench;

fn main() {
    let seed = 42;

    println!("## partitioner micro-benchmarks (per-call latency)\n");
    for (name, a) in [
        ("mc2depi_s(96)", gen::mc2depi_s(96, seed)),
        ("scircuit_s(8192)", gen::scircuit_s(8192, seed + 7)),
        ("cant_s(2048)", gen::cant_s(2048, seed)),
    ] {
        let g = a.affinity_graph();
        let k = g.m().div_ceil(exp::BLOCK_SIZE).max(2);
        println!("{name}: n={} m={} k={k}", g.n, g.m());

        let s = bench("  ep::task_graph (transform)", 1, 10, || {
            ep::task_graph(&g, ep::ChainOrder::Index, seed)
        });
        println!("{}", s.row());

        let s = bench("  ep::partition_edges (full EP)", 1, 5, || {
            let mut o = ep::EpOpts::default();
            o.vp.seed = seed;
            ep::partition_edges(&g, k, &o)
        });
        println!("{}", s.row());

        let s = bench("  powergraph greedy", 1, 5, || {
            Method::PgGreedy.partition(&g, k, seed)
        });
        println!("{}", s.row());

        let s = bench("  hypergraph (baseline)", 0, 2, || {
            hypergraph::partition_edges(
                &g,
                k,
                &hypergraph::HpOpts { seed, ..Default::default() },
            )
        });
        println!("{}", s.row());
        println!();
    }

    println!("## Fig 6: partition model comparison (quality + one-shot time)\n");
    exp::fig6_table(&exp::fig6_partition(seed)).print();

    println!("\n## partition-time scaling (EP vs HP as graphs grow)\n");
    exp::partition_scaling_table(seed).print();

    println!("\n## ablations (DESIGN.md §6)\n");
    exp::ablation_table(seed).print();
}
