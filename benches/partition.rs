//! Partitioner benchmarks — the perf-rewrite headline (optimized vs
//! retained seed pipeline on a ≥1M-edge graph at k=64, recorded in
//! BENCH_partition.json), plus Fig 6 (method comparison), the
//! partition-time scaling claim ("orders of magnitude faster than
//! hypergraph"), and the DESIGN.md ablations.
//!
//!     cargo bench --offline --bench partition
//!
//! Set EPGRAPH_BENCH_SMOKE=1 for a fast CI-sized run (the JSON baseline
//! records the mode, so full and smoke baselines are never confused).
//!
//! criterion is unavailable offline; this uses the in-repo harness
//! (epgraph::util::benchkit) with warmup + multi-iteration stats.

use epgraph::graph::gen as ggen;
use epgraph::experiments as exp;
use epgraph::partition::{ep, hypergraph, quality, reference, Method};
use epgraph::sparse::gen;
use epgraph::util::benchkit::{bench, time_once, JsonReport};

/// Headline: the rewrite's speedup over the retained seed pipeline on a
/// power-law task graph, single-threaded (algorithmic gain alone) and
/// multi-threaded (scaling on top), with cut-quality parity recorded.
fn perf_headline(seed: u64) {
    let smoke = std::env::var("EPGRAPH_BENCH_SMOKE").is_ok();
    // power_law(n, 3) has m ~= 3n tasks; full mode crosses 1M edges
    let n = if smoke { 60_000 } else { 350_000 };
    let k = 64;
    println!("## perf-rewrite headline ({})\n", if smoke { "smoke" } else { "full" });
    let g = ggen::power_law(n, 3, seed);
    println!("power_law({n}, 3): n={} m={} k={k}", g.n, g.m());

    let opts_1t = {
        let mut o = ep::EpOpts::default();
        o.vp.seed = seed;
        o.vp.threads = 1;
        o
    };
    let opts_mt = {
        let mut o = opts_1t.clone();
        o.vp.threads = 0; // one per core
        o
    };

    let (p_ref, t_ref) = time_once(|| reference::partition_edges_naive(&g, k, &opts_1t));
    let (p_1t, t_1t) = time_once(|| ep::partition_edges(&g, k, &opts_1t));
    let (p_mt, t_mt) = time_once(|| ep::partition_edges(&g, k, &opts_mt));

    let cut_ref = quality::vertex_cut_cost(&g, &p_ref);
    let cut_new = quality::vertex_cut_cost(&g, &p_1t);
    let cut_mt = quality::vertex_cut_cost(&g, &p_mt);
    assert_eq!(p_1t.assign, p_mt.assign, "thread count must not change the partition");

    let s1 = t_ref.as_secs_f64() / t_1t.as_secs_f64().max(1e-9);
    let smt = t_ref.as_secs_f64() / t_mt.as_secs_f64().max(1e-9);
    println!("  seed pipeline (reference): {:>10.3}s  cut={cut_ref}", t_ref.as_secs_f64());
    println!("  rewrite, 1 thread:         {:>10.3}s  cut={cut_new}  speedup={s1:.2}x", t_1t.as_secs_f64());
    println!("  rewrite, all cores:        {:>10.3}s  cut={cut_mt}  speedup={smt:.2}x", t_mt.as_secs_f64());

    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    let mut r = JsonReport::new();
    r.str("bench", "partition")
        .str("mode", if smoke { "smoke" } else { "full" })
        .raw(
            "graph",
            &format!("{{\"generator\": \"power_law\", \"n\": {}, \"m\": {}}}", g.n, g.m()),
        )
        .int("k", k as u64)
        .int("seed", seed)
        .int("cores", cores as u64)
        .num("ref_secs", t_ref.as_secs_f64())
        .num("new_1t_secs", t_1t.as_secs_f64())
        .num("new_mt_secs", t_mt.as_secs_f64())
        .num("speedup_single_thread", s1)
        .num("speedup_multi_thread", smt)
        .int("ref_cut", cut_ref)
        .int("new_cut", cut_new)
        .num("cut_ratio_new_over_ref", cut_new as f64 / cut_ref.max(1) as f64);
    match r.write("BENCH_partition.json") {
        Ok(()) => println!("  baseline written to BENCH_partition.json\n"),
        Err(e) => println!("  WARNING: could not write BENCH_partition.json: {e}\n"),
    }
}

fn main() {
    let seed = 42;

    perf_headline(seed);

    println!("## partitioner micro-benchmarks (per-call latency)\n");
    for (name, a) in [
        ("mc2depi_s(96)", gen::mc2depi_s(96, seed)),
        ("scircuit_s(8192)", gen::scircuit_s(8192, seed + 7)),
        ("cant_s(2048)", gen::cant_s(2048, seed)),
    ] {
        let g = a.affinity_graph();
        let k = g.m().div_ceil(exp::BLOCK_SIZE).max(2);
        println!("{name}: n={} m={} k={k}", g.n, g.m());

        let s = bench("  ep::task_graph (transform)", 1, 10, || {
            ep::task_graph(&g, ep::ChainOrder::Index, seed)
        });
        println!("{}", s.row());

        let s = bench("  ep::partition_edges (full EP)", 1, 5, || {
            let mut o = ep::EpOpts::default();
            o.vp.seed = seed;
            ep::partition_edges(&g, k, &o)
        });
        println!("{}", s.row());

        let s = bench("  powergraph greedy", 1, 5, || {
            Method::PgGreedy.partition(&g, k, seed)
        });
        println!("{}", s.row());

        let s = bench("  hypergraph (baseline)", 0, 2, || {
            hypergraph::partition_edges(
                &g,
                k,
                &hypergraph::HpOpts { seed, ..Default::default() },
            )
        });
        println!("{}", s.row());
        println!();
    }

    println!("## Fig 6: partition model comparison (quality + one-shot time)\n");
    exp::fig6_table(&exp::fig6_partition(seed)).print();

    println!("\n## partition-time scaling (EP vs HP as graphs grow)\n");
    exp::partition_scaling_table(seed).print();

    println!("\n## ablations (DESIGN.md §6)\n");
    exp::ablation_table(seed).print();
}
