//! End-to-end SPMV/CG benchmarks — regenerates Table 2, Fig 10, Fig 11,
//! Fig 12 and Table 3, plus PJRT hot-path latencies (the L3 perf-pass
//! targets of EXPERIMENTS.md §Perf).
//!
//!     make artifacts && cargo bench --offline --bench spmv_e2e

use epgraph::coordinator::{run_cg, CgRunConfig};
use epgraph::experiments as exp;
use epgraph::gpusim::GpuConfig;
use epgraph::partition::Method;
use epgraph::runtime::{default_artifacts_dir, Engine, SpmvExec};
use epgraph::sparse::{gen, pack_blocked, BlockedShape};
use epgraph::util::benchkit::bench;
use epgraph::util::rng::Pcg32;

fn main() -> anyhow::Result<()> {
    let seed = 42;
    let gpu = GpuConfig::default();

    println!("## PJRT hot path (request-path latency, CPU PJRT)\n");
    {
        let mut engine = Engine::load(&default_artifacts_dir())?;
        let a = gen::spd_poisson(64); // 4096 unknowns
        let g = a.affinity_graph();
        let p = Method::Ep.partition(&g, 40, seed);
        let blocked = pack_blocked(
            &a,
            &p,
            BlockedShape { n_in: 4096, n_out: 4096, k: 40, e: 1024, c: 1024 },
        )?;
        let mut rng = Pcg32::new(seed);
        let x: Vec<f32> = (0..a.ncols).map(|_| rng.gen_f32()).collect();

        let t0 = std::time::Instant::now();
        let exec = SpmvExec::prepare(&mut engine, &blocked)?;
        println!("artifact compile+prepare (config {}): {:?}", exec.config(), t0.elapsed());

        let s = bench("spmv execute (pjrt, 4096x4096 ~20k nnz)", 3, 20, || {
            exec.run(&x).unwrap()
        });
        println!("{}", s.row());

        let s = bench("spmv reference (rust blocked interpreter)", 3, 20, || {
            blocked.execute_ref(&x)
        });
        println!("{}", s.row());

        let s = bench("coo spmv (plain rust loop)", 3, 20, || a.spmv(&x));
        println!("{}", s.row());
    }

    println!("\n## full CG solve (EP-adapt, PJRT numerics + simulator)\n");
    {
        let mut engine = Engine::load(&default_artifacts_dir())?;
        let a = gen::spd_poisson(64);
        let mut rng = Pcg32::new(7);
        let rhs: Vec<f32> = (0..a.nrows).map(|_| rng.gen_f32() - 0.5).collect();
        for wait in [false, true] {
            let cfg = CgRunConfig {
                block_size: 512,
                max_iters: 300,
                wait_for_optimizer: wait,
                ..Default::default()
            };
            let r = run_cg(&mut engine, &a, &rhs, &cfg)?;
            println!(
                "{}: {} iters, wall {:?}, sim speedup {:?}, fell_back {}",
                if wait { "EP-ideal" } else { "EP-adapt" },
                r.iterations,
                r.wall_time,
                r.kernel_speedup().map(|s| format!("{s:.2}x")),
                r.fell_back
            );
        }
    }

    println!("\n## Table 2 + Fig 10/11/12 (simulated GPU, 8-matrix suite)\n");
    let cases = exp::table2_cases(&gpu, seed);
    exp::table2_table(&cases).print();
    println!();
    exp::fig10_table(&cases).print();
    println!();
    exp::fig11_table(&cases).print();
    println!();
    exp::fig12_table(&cases).print();

    println!("\n## Table 3: block-size sweep\n");
    exp::table3_table(&gpu, seed).print();
    Ok(())
}
