//! Simulator + application benchmarks — regenerates Fig 13/14/15 and
//! measures the simulator's own throughput (it must stay cheap enough
//! to run inside the adaptive controller's decision loop).
//!
//!     cargo bench --offline --bench gpusim

use epgraph::apps;
use epgraph::experiments as exp;
use epgraph::gpusim::{cache::SetAssocLru, sim_original, sim_task_graph, GpuConfig};
use epgraph::partition::Method;
use epgraph::sparse::cpack;
use epgraph::util::benchkit::bench;

fn main() {
    let seed = 42;
    let gpu = GpuConfig::default();

    println!("## simulator throughput\n");
    {
        let app = apps::cfd(110, seed);
        let g = &app.graph;
        let p = Method::Ep.partition(g, g.m().div_ceil(256), seed);
        let layout = cpack::cpack_graph(g, &p);

        let s = bench("sim_original (cfd, 36k tasks)", 2, 10, || {
            sim_original(&gpu, g, 256)
        });
        println!("{}", s.row());

        let s = bench("sim_task_graph smem (cfd, 36k tasks)", 2, 10, || {
            sim_task_graph(&gpu, g, &p, Some(&layout), true)
        });
        println!("{}", s.row());

        let s = bench("sim_task_graph tex (cfd, 36k tasks)", 2, 10, || {
            sim_task_graph(&gpu, g, &p, Some(&layout), false)
        });
        println!("{}", s.row());

        let s = bench("texture cache 1M accesses", 1, 5, || {
            let mut c = SetAssocLru::new(48 * 1024, 32, 4);
            let mut acc = 0u64;
            for i in 0..1_000_000u32 {
                if c.access_elem(i % 40_000, 4) {
                    acc += 1;
                }
            }
            acc
        });
        println!("{}", s.row());
    }

    println!("\n## Fig 13/14/15: application suite (original vs EP-adapt)\n");
    let cases = exp::fig13_cases(&gpu, seed);
    exp::fig13_table(&cases).print();
    println!();
    exp::fig14_table(&cases).print();
    println!();
    exp::fig15_table(&cases).print();

    println!("\n## headline: {}", exp::redundancy_headline(seed));
}
