//! Serving-layer benchmarks: cache hit-path latency over real loopback
//! TCP, the raw cache/fingerprint costs, the PR 7 headline — the
//! event-driven reactor's pipelined hit-path throughput at ≥1k open
//! connections against an in-bench thread-per-connection baseline —
//! and the PR 8 fleet hit path: owned-hit vs forwarded-hit latency in
//! a two-node consistent-hash fleet (`forwarded_hit_overhead` is the
//! gated ratio).
//!
//!     cargo bench --offline --bench service
//!
//! Set EPGRAPH_BENCH_SMOKE=1 for a fast CI-sized run (1024 connections;
//! the full run opens 10k and wants `ulimit -n` ≥ 32768).  Latency rows
//! are printed; the throughput comparison is also written to
//! BENCH_service.json for the CI regression gate (`serve_pipelined_speedup`
//! is the gated ratio — wall-clock rps is machine-dependent and is not).
//!
//! The baseline server is deliberately the pre-PR-7 shape: one blocking
//! 128KiB-stack thread per accepted connection, sharing the exact same
//! per-request hit path as the reactor (decode -> resolve -> fingerprint
//! -> cache.get -> encode), so the measured gap is the architecture —
//! pipelining plus micro-batched writes — not a different code path.
//!
//! criterion is unavailable offline; this uses the in-repo harness
//! (epgraph::util::benchkit).

use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use epgraph::coordinator::{optimize_graph_with_breakdown, OptOptions};
use epgraph::graph::Graph;
use epgraph::service::{
    fingerprint, proto, CachedSchedule, Client, GraphSpec, HashRing, PipelinedClient,
    ScheduleCache, ServeOpts, Server,
};
use epgraph::util::benchkit::{bench, JsonReport, Stats};
use epgraph::util::json::Json;

/// Client-side driver threads for the throughput phases.  All N
/// connections stay open on the server for the whole phase; the drivers
/// cycle through their share issuing bursts, so the server always holds
/// N live sockets while ~DRIVERS of them carry traffic at any instant.
const DRIVERS: usize = 8;

/// Give up on a throughput phase below this many connections — the
/// "at ≥1k connections" headline would be meaningless.
const MIN_CONNS: usize = 64;

fn main() {
    let smoke = std::env::var("EPGRAPH_BENCH_SMOKE").is_ok();
    let iters = if smoke { 200 } else { 2000 };
    let want_conns = if smoke { 1024 } else { 10_000 };
    let reqs_per_conn = if smoke { 16 } else { 32 };
    let depth = 32;

    let spec = GraphSpec::Gen { name: "cfd_mesh".into(), args: vec![24, 24, 1] };
    let opts = OptOptions { k: 8, seed: 7, ..Default::default() };
    let g = spec.resolve().expect("resolve bench graph");
    println!(
        "## service benchmarks ({}) — workload cfd_mesh:24,24,1 (n={} m={} k={})\n",
        if smoke { "smoke" } else { "full" },
        g.n,
        g.m(),
        opts.k
    );

    // --- raw building blocks -------------------------------------------
    println!("{}", bench("fingerprint (graph+opts)", 10, iters, || fingerprint(&g, &opts)).row());

    let (sched, bd) = optimize_graph_with_breakdown(&g, &opts);
    let entry = Arc::new(CachedSchedule::new(sched, bd, Arc::new(g.clone())));
    let cache = Arc::new(ScheduleCache::new(64 << 20, 8));
    let fp = fingerprint(&g, &opts);
    cache.insert(fp, entry);
    println!("{}", bench("cache get (hit, in-process)", 10, iters, || cache.get(fp)).row());

    // --- end-to-end hit path over loopback TCP (reactor) ---------------
    let server = Arc::new(
        Server::bind(ServeOpts { port: 0, threads: 2, ..Default::default() })
            .expect("bind loopback"),
    );
    let addr = server.local_addr();
    let run = {
        let server = server.clone();
        std::thread::spawn(move || server.run().expect("server run"))
    };

    let mut client = Client::connect(addr).expect("connect");
    let line = proto::optimize_request(&spec, &opts).dump();
    // warm the cache (the one and only optimizer run)
    let first = client.roundtrip_line(&line).expect("first request");
    assert_eq!(
        first.get("cached").and_then(|v| v.as_str()),
        Some("miss"),
        "first request must be a miss"
    );

    println!(
        "{}",
        bench("serve hit path (TCP roundtrip)", 10, iters, || {
            client.roundtrip_line(&line).expect("hit request")
        })
        .row()
    );

    // --- throughput: pipelined reactor vs thread-per-connection --------
    println!("\n## hit-path throughput at scale (target {want_conns} conns)\n");

    // Baseline first, against its own throwaway server, so its threads
    // are gone before the reactor phase opens its connection flood.
    let (base_addr, base_stop) = spawn_baseline_server(cache.clone());
    let (blocking_rps, blocking_conns) =
        blocking_throughput(base_addr, &line, want_conns, reqs_per_conn);
    base_stop.store(true, Ordering::Release);
    let _ = TcpStream::connect(base_addr); // unblock the accept loop
    println!(
        "thread-per-conn baseline: {blocking_conns} conns x {reqs_per_conn} req, depth 1  \
         -> {blocking_rps:.0} req/s"
    );

    let (pipelined_rps, pipelined_conns) =
        pipelined_throughput(addr, &line, want_conns, reqs_per_conn, depth);
    let speedup = pipelined_rps / blocking_rps;
    println!(
        "pipelined reactor:        {pipelined_conns} conns x {reqs_per_conn} req, depth {depth} \
         -> {pipelined_rps:.0} req/s"
    );
    println!("serve_pipelined_speedup: {speedup:.2}x");

    let stats = client.roundtrip_line(&proto::simple_request("stats").dump()).expect("stats");
    println!("\nstats after run: {}", stats.dump());
    client.roundtrip_line(&proto::simple_request("shutdown").dump()).expect("shutdown");
    run.join().expect("server thread");

    // --- fleet: owned hit vs forwarded hit -----------------------------
    println!("\n## fleet hit path (2-node consistent-hash fleet)\n");
    let fleet_iters = if smoke { 100 } else { 500 };
    let (owned_stats, forwarded_stats) = fleet_hit_phase(&spec, &g, fleet_iters);
    println!("{}", owned_stats.row());
    println!("{}", forwarded_stats.row());
    let overhead =
        forwarded_stats.median.as_secs_f64() / owned_stats.median.as_secs_f64().max(1e-9);
    println!("forwarded_hit_overhead: {overhead:.2}x (median over median)");

    let mut report = JsonReport::new();
    report
        .str("bench", "service")
        .str("mode", if smoke { "smoke" } else { "full" })
        .str("workload", "cfd_mesh:24,24,1 k=8")
        .int("conns_blocking", blocking_conns as u64)
        .int("conns_pipelined", pipelined_conns as u64)
        .int("requests_per_conn", reqs_per_conn as u64)
        .int("pipeline_depth", depth as u64)
        .num("serve_blocking_rps", blocking_rps)
        .num("serve_pipelined_rps", pipelined_rps)
        .num("serve_pipelined_speedup", speedup)
        .num("fleet_owned_hit_ms", owned_stats.median.as_secs_f64() * 1e3)
        .num("fleet_forwarded_hit_ms", forwarded_stats.median.as_secs_f64() * 1e3)
        .num("forwarded_hit_overhead", overhead);
    report.write("BENCH_service.json").expect("write BENCH_service.json");
    println!("\nwrote BENCH_service.json");
}

/// The pre-reactor server shape: blocking accept loop, one 128KiB-stack
/// handler thread per connection, strict request->response lockstep.
/// Serves only the warmed hit path — identical per-request work to the
/// reactor (decode, resolve, fingerprint, cache.get, encode).
fn spawn_baseline_server(cache: Arc<ScheduleCache>) -> (SocketAddr, Arc<AtomicBool>) {
    let listener = TcpListener::bind(("127.0.0.1", 0)).expect("bind baseline");
    let addr = listener.local_addr().expect("baseline addr");
    let stop = Arc::new(AtomicBool::new(false));
    let stop2 = stop.clone();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop2.load(Ordering::Acquire) {
                break;
            }
            let Ok(stream) = conn else { continue };
            let cache = cache.clone();
            let spawned = std::thread::Builder::new()
                .name("bench-baseline-conn".into())
                .stack_size(128 << 10)
                .spawn(move || baseline_conn(stream, &cache));
            if spawned.is_err() {
                // Thread exhaustion: drop the connection; the client's
                // connect-or-roundtrip failure triggers its fallback.
                continue;
            }
        }
    });
    (addr, stop)
}

fn baseline_conn(stream: TcpStream, cache: &ScheduleCache) {
    stream.set_nodelay(true).ok();
    let Ok(mut writer) = stream.try_clone() else { return };
    let reader = BufReader::new(stream);
    for raw in reader.lines() {
        let Ok(raw) = raw else { return };
        let resp = baseline_reply(&raw, cache);
        if writer.write_all(resp.dump().as_bytes()).is_err() || writer.write_all(b"\n").is_err() {
            return;
        }
    }
}

fn baseline_reply(raw: &str, cache: &ScheduleCache) -> Json {
    let parsed = match Json::parse(raw) {
        Ok(j) => j,
        Err(e) => return proto::error_response(&format!("bad json: {e}"), None),
    };
    let id = proto::request_id(&parsed);
    let req = match proto::decode_request(&parsed) {
        Ok(r) => r,
        Err(e) => return proto::Reply::Error { msg: e, retry_after_ms: None }.encode(id.as_ref()),
    };
    let proto::Op::Optimize { graph, opts, .. } = req.op else {
        return proto::Reply::Error {
            msg: "baseline serves optimize only".into(),
            retry_after_ms: None,
        }
        .encode(id.as_ref());
    };
    let g = match graph.resolve() {
        Ok(g) => g,
        Err(e) => return proto::Reply::Error { msg: e, retry_after_ms: None }.encode(id.as_ref()),
    };
    let fp = fingerprint(&g, &opts);
    match cache.get(fp) {
        Some(entry) => proto::Reply::Schedule {
            fp,
            cached: "hit",
            entry: &entry,
            queue_ms: None,
            optimize_ms: None,
        }
        .encode(id.as_ref()),
        None => proto::Reply::Error { msg: "baseline cache cold".into(), retry_after_ms: None }
            .encode(id.as_ref()),
    }
}

/// Open up to `want` blocking clients, then drive `reqs` lockstep
/// roundtrips on each from DRIVERS threads.  Returns (req/s, conns).
fn blocking_throughput(addr: SocketAddr, line: &str, want: usize, reqs: usize) -> (f64, usize) {
    let mut clients = Vec::with_capacity(want);
    for _ in 0..want {
        match Client::connect(addr) {
            Ok(c) => clients.push(c),
            Err(e) => {
                eprintln!("baseline connect fallback at {} conns: {e}", clients.len());
                break;
            }
        }
    }
    let conns = clients.len();
    assert!(conns >= MIN_CONNS, "only {conns} baseline connections — raise ulimit -n");

    let done = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for chunk in chunks(clients, DRIVERS) {
            let done = &done;
            s.spawn(move || {
                let mut chunk = chunk;
                for client in chunk.iter_mut() {
                    for _ in 0..reqs {
                        let resp = client.roundtrip_line(line).expect("baseline roundtrip");
                        assert_eq!(resp.get("cached").and_then(|v| v.as_str()), Some("hit"));
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let total = done.load(Ordering::Relaxed);
    assert_eq!(total as usize, conns * reqs, "baseline lost responses");
    (total as f64 / secs.max(1e-9), conns)
}

/// Open up to `want` pipelined clients against the reactor, then drive
/// a `depth`-deep sliding window of `reqs` requests on each from
/// DRIVERS threads.  Returns (req/s, conns).
fn pipelined_throughput(
    addr: SocketAddr,
    line: &str,
    want: usize,
    reqs: usize,
    depth: usize,
) -> (f64, usize) {
    let req = Json::parse(line).expect("request json");
    let mut clients = Vec::with_capacity(want);
    for _ in 0..want {
        match PipelinedClient::connect(addr) {
            Ok(c) => clients.push(c),
            Err(e) => {
                eprintln!("reactor connect fallback at {} conns: {e}", clients.len());
                break;
            }
        }
    }
    let conns = clients.len();
    assert!(conns >= MIN_CONNS, "only {conns} reactor connections — raise ulimit -n");

    let done = AtomicU64::new(0);
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for chunk in chunks(clients, DRIVERS) {
            let (done, req) = (&done, &req);
            s.spawn(move || {
                let mut chunk = chunk;
                for client in chunk.iter_mut() {
                    let mut sent = 0usize;
                    let mut got = 0usize;
                    while got < reqs {
                        while sent < reqs && client.in_flight() < depth {
                            client.submit(req).expect("submit");
                            sent += 1;
                        }
                        let (_ticket, resp) = client.recv().expect("pipelined recv");
                        assert_eq!(resp.get("cached").and_then(|v| v.as_str()), Some("hit"));
                        got += 1;
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            });
        }
    });
    let secs = t0.elapsed().as_secs_f64();
    let total = done.load(Ordering::Relaxed);
    assert_eq!(total as usize, conns * reqs, "reactor lost responses");
    (total as f64 / secs.max(1e-9), conns)
}

/// Stand up a two-node fleet on pre-reserved ports, prime the owner's
/// cache with one optimizer run, then measure the warmed hit path two
/// ways: client -> owner directly ("owned"), and client -> the other
/// node, which relays to the owner over its peer link ("forwarded").
/// The forwarding node never caches relayed results, so every one of
/// its requests takes the full forward hop.  Returns (owned, forwarded).
fn fleet_hit_phase(spec: &GraphSpec, g: &Graph, iters: usize) -> (Stats, Stats) {
    // Reserve both ports while holding both listeners so they cannot
    // collide, then release them for the servers to claim.
    let la = TcpListener::bind(("127.0.0.1", 0)).expect("reserve port a");
    let lb = TcpListener::bind(("127.0.0.1", 0)).expect("reserve port b");
    let (pa, pb) = (
        la.local_addr().expect("addr a").port(),
        lb.local_addr().expect("addr b").port(),
    );
    drop((la, lb));
    let peers = vec![format!("127.0.0.1:{pa}"), format!("127.0.0.1:{pb}")];
    let ring = HashRing::new(&peers).expect("fleet ring");

    // Pick a seed whose fingerprint node A owns, so the owned/forwarded
    // roles below are deterministic.
    let mut seed = 7u64;
    let fleet_opts = loop {
        let o = OptOptions { k: 8, seed, ..Default::default() };
        if ring.owner(fingerprint(g, &o)) == peers[0] {
            break o;
        }
        seed += 1;
    };

    let spawn_member = |port: u16| {
        let server = Arc::new(
            Server::bind(ServeOpts { port, threads: 2, peers: peers.clone(), ..Default::default() })
                .expect("bind fleet member"),
        );
        let run = {
            let server = server.clone();
            std::thread::spawn(move || server.run().expect("fleet member run"))
        };
        (server, run)
    };
    let (node_a, run_a) = spawn_member(pa);
    let (node_b, run_b) = spawn_member(pb);

    let line = proto::optimize_request(spec, &fleet_opts).dump();
    let mut ca = Client::connect(node_a.local_addr()).expect("connect node A");
    let mut cb = Client::connect(node_b.local_addr()).expect("connect node B");
    let first = ca.roundtrip_line(&line).expect("prime owner");
    assert_eq!(
        first.get("cached").and_then(|v| v.as_str()),
        Some("miss"),
        "fleet prime must be a miss"
    );
    let via_b = cb.roundtrip_line(&line).expect("first forwarded request");
    assert_eq!(
        via_b.get("cached").and_then(|v| v.as_str()),
        Some("hit"),
        "peer must relay the owner's cache hit"
    );

    let owned = bench("fleet owned hit (client -> owner)", 10, iters, || {
        ca.roundtrip_line(&line).expect("owned hit")
    });
    let forwarded = bench("fleet forwarded hit (client -> peer -> owner)", 10, iters, || {
        cb.roundtrip_line(&line).expect("forwarded hit")
    });

    let stats_b = cb.roundtrip_line(&proto::simple_request("stats").dump()).expect("stats B");
    let relayed = stats_b.get("forwarded").and_then(|v| v.as_u64()).unwrap_or(0);
    assert!(relayed > 0, "node B must have forwarded requests: {}", stats_b.dump());

    ca.roundtrip_line(&proto::simple_request("shutdown").dump()).expect("shutdown A");
    cb.roundtrip_line(&proto::simple_request("shutdown").dump()).expect("shutdown B");
    run_a.join().expect("node A thread");
    run_b.join().expect("node B thread");
    (owned, forwarded)
}

/// Split `items` into at most `n` contiguous chunks of near-equal size.
fn chunks<T>(mut items: Vec<T>, n: usize) -> Vec<Vec<T>> {
    let per = items.len().div_ceil(n).max(1);
    let mut out = Vec::new();
    while !items.is_empty() {
        let take = per.min(items.len());
        out.push(items.drain(..take).collect());
    }
    out
}
