//! Serving-layer benchmarks: cache hit-path latency over real loopback
//! TCP, singleflight fan-in, and the raw cache/fingerprint costs.
//!
//!     cargo bench --offline --bench service
//!
//! Set EPGRAPH_BENCH_SMOKE=1 for a fast CI-sized run.  Results are
//! printed (not written to BENCH_partition.json — the serving numbers
//! are latency distributions, not the ratio metrics the regression gate
//! consumes; PERF.md records representative figures).
//!
//! criterion is unavailable offline; this uses the in-repo harness
//! (epgraph::util::benchkit).

use std::sync::Arc;

use epgraph::coordinator::{optimize_graph_with_breakdown, OptOptions};
use epgraph::service::{
    fingerprint, proto, CachedSchedule, Client, GraphSpec, ScheduleCache, ServeOpts, Server,
};
use epgraph::util::benchkit::bench;

fn main() {
    let smoke = std::env::var("EPGRAPH_BENCH_SMOKE").is_ok();
    let iters = if smoke { 200 } else { 2000 };

    let spec = GraphSpec::Gen { name: "cfd_mesh".into(), args: vec![24, 24, 1] };
    let opts = OptOptions { k: 8, seed: 7, ..Default::default() };
    let g = spec.resolve().expect("resolve bench graph");
    println!(
        "## service benchmarks ({}) — workload cfd_mesh:24,24,1 (n={} m={} k={})\n",
        if smoke { "smoke" } else { "full" },
        g.n,
        g.m(),
        opts.k
    );

    // --- raw building blocks -------------------------------------------
    println!("{}", bench("fingerprint (graph+opts)", 10, iters, || fingerprint(&g, &opts)).row());

    let (sched, bd) = optimize_graph_with_breakdown(&g, &opts);
    let entry = Arc::new(CachedSchedule::new(sched, bd));
    let cache = ScheduleCache::new(64 << 20, 8);
    let fp = fingerprint(&g, &opts);
    cache.insert(fp, entry);
    println!("{}", bench("cache get (hit, in-process)", 10, iters, || cache.get(fp)).row());

    // --- end-to-end hit path over loopback TCP -------------------------
    let server = Arc::new(
        Server::bind(ServeOpts { port: 0, threads: 2, ..Default::default() })
            .expect("bind loopback"),
    );
    let addr = server.local_addr();
    let run = {
        let server = server.clone();
        std::thread::spawn(move || server.run().expect("server run"))
    };

    let mut client = Client::connect(addr).expect("connect");
    let line = proto::optimize_request(&spec, &opts).dump();
    // warm the cache (the one and only optimizer run)
    let first = client.roundtrip_line(&line).expect("first request");
    assert_eq!(
        first.get("cached").and_then(|v| v.as_str()),
        Some("miss"),
        "first request must be a miss"
    );

    println!(
        "{}",
        bench("serve hit path (TCP roundtrip)", 10, iters, || {
            client.roundtrip_line(&line).expect("hit request")
        })
        .row()
    );

    let stats = client.roundtrip_line(&proto::simple_request("stats").dump()).expect("stats");
    println!("\nstats after run: {}", stats.dump());
    client.roundtrip_line(&proto::simple_request("shutdown").dump()).expect("shutdown");
    run.join().expect("server thread");
}
